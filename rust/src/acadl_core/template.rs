//! Templates and dangling edges (§4.2, Listings 2–3).
//!
//! Templates are plain Rust structs that instantiate their objects and
//! internal edges into an [`Ag`] and expose [`DanglingEdge`]s — half-edges
//! with only a source or only a target — as their interface.  Dangling
//! edges are later connected to each other (or directly to an object) with
//! [`connect_dangling`] / [`connect_dangling_to`], which re-runs the class-
//! diagram validity check.  An unconnected dangling edge simply never
//! materializes (the paper: "When a dangling edge is not connected later
//! on, no edge will be instantiated").

use thiserror::Error;

use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::{Ag, AgError, ObjId};

/// A half-edge exposed by a template: exactly one endpoint is set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DanglingEdge {
    pub kind: EdgeKind,
    pub source: Option<ObjId>,
    pub target: Option<ObjId>,
}

impl DanglingEdge {
    /// A dangling edge with a known source, awaiting its target.
    pub fn from_source(kind: EdgeKind, source: ObjId) -> Self {
        DanglingEdge {
            kind,
            source: Some(source),
            target: None,
        }
    }

    /// A dangling edge with a known target, awaiting its source.
    pub fn to_target(kind: EdgeKind, target: ObjId) -> Self {
        DanglingEdge {
            kind,
            source: None,
            target: Some(target),
        }
    }
}

#[derive(Debug, Error)]
pub enum TemplateError {
    #[error("dangling edges have mismatched types: {0} vs {1}")]
    KindMismatch(EdgeKind, EdgeKind),
    #[error("cannot connect: need one source-dangling and one target-dangling edge")]
    EndpointConflict,
    #[error(transparent)]
    Ag(#[from] AgError),
}

/// Connect two dangling edges into a real, validated edge — the Python
/// front-end's `connect_dangling_edge(a, b)`.  One must carry the source,
/// the other the target; their edge types must agree.
pub fn connect_dangling(
    ag: &mut Ag,
    a: DanglingEdge,
    b: DanglingEdge,
) -> Result<(), TemplateError> {
    if a.kind != b.kind {
        return Err(TemplateError::KindMismatch(a.kind, b.kind));
    }
    let (src, dst) = match (a.source, a.target, b.source, b.target) {
        (Some(s), None, None, Some(t)) => (s, t),
        (None, Some(t), Some(s), None) => (s, t),
        _ => return Err(TemplateError::EndpointConflict),
    };
    ag.connect(src, dst, a.kind)?;
    Ok(())
}

/// Connect a dangling edge directly to an object (the overload the paper
/// describes for e.g. wiring a template port straight to the DRAM object).
pub fn connect_dangling_to(
    ag: &mut Ag,
    e: DanglingEdge,
    obj: ObjId,
) -> Result<(), TemplateError> {
    let (src, dst) = match (e.source, e.target) {
        (Some(s), None) => (s, obj),
        (None, Some(t)) => (obj, t),
        _ => return Err(TemplateError::EndpointConflict),
    };
    ag.connect(src, dst, e.kind)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::data::Data;
    use crate::acadl_core::latency::Latency;
    use crate::acadl_core::object::build;

    /// The PE template of Listing 2, reduced to its connective essentials.
    struct Pe {
        fu_outgoing_write: DanglingEdge,
        rf_ingoing_write: DanglingEdge,
    }

    impl Pe {
        fn new(ag: &mut Ag, row: usize, col: usize) -> Self {
            let ex = ag
                .add(build::execute_stage(&format!("ex[{row}][{col}]"), 1))
                .unwrap();
            let fu = ag
                .add(build::functional_unit(
                    &format!("fu[{row}][{col}]"),
                    &["mac"],
                    Latency::Const(1),
                ))
                .unwrap();
            let rf = ag
                .add(build::register_file(
                    &format!("rf[{row}][{col}]"),
                    32,
                    vec![(format!("r{row}_{col}_a"), Data::f32(0.0))],
                ))
                .unwrap();
            ag.connect(ex, fu, EdgeKind::Contains).unwrap();
            ag.connect(rf, fu, EdgeKind::ReadData).unwrap();
            ag.connect(fu, rf, EdgeKind::WriteData).unwrap();
            Pe {
                fu_outgoing_write: DanglingEdge::from_source(EdgeKind::WriteData, fu),
                rf_ingoing_write: DanglingEdge::to_target(EdgeKind::WriteData, rf),
            }
        }
    }

    #[test]
    fn connect_two_templates() {
        let mut ag = Ag::new();
        let a = Pe::new(&mut ag, 0, 0);
        let b = Pe::new(&mut ag, 1, 0);
        let edges_before = ag.edges.len();
        connect_dangling(&mut ag, a.fu_outgoing_write, b.rf_ingoing_write).unwrap();
        assert_eq!(ag.edges.len(), edges_before + 1);
        // Order-independent: (target, source) works too.
        connect_dangling(&mut ag, b.rf_ingoing_write, a.fu_outgoing_write).unwrap();
    }

    #[test]
    fn kind_mismatch_rejected() {
        let mut ag = Ag::new();
        let a = Pe::new(&mut ag, 0, 0);
        let wrong = DanglingEdge::to_target(
            EdgeKind::ReadData,
            a.rf_ingoing_write.target.unwrap(),
        );
        assert!(matches!(
            connect_dangling(&mut ag, a.fu_outgoing_write, wrong),
            Err(TemplateError::KindMismatch(..))
        ));
    }

    #[test]
    fn endpoint_conflict_rejected() {
        let mut ag = Ag::new();
        let a = Pe::new(&mut ag, 0, 0);
        let b = Pe::new(&mut ag, 1, 0);
        // Two source-dangling edges cannot be joined.
        assert!(matches!(
            connect_dangling(&mut ag, a.fu_outgoing_write, b.fu_outgoing_write),
            Err(TemplateError::EndpointConflict)
        ));
    }

    #[test]
    fn connect_to_object_directly() {
        let mut ag = Ag::new();
        let a = Pe::new(&mut ag, 0, 0);
        let rf2 = ag
            .add(build::register_file(
                "rf_ext",
                32,
                vec![("ext0".into(), Data::f32(0.0))],
            ))
            .unwrap();
        connect_dangling_to(&mut ag, a.fu_outgoing_write, rf2).unwrap();
        // Invalid direct connection still rejected by edge rules.
        let ex2 = ag.add(build::execute_stage("ex_ext", 1)).unwrap();
        assert!(connect_dangling_to(&mut ag, a.fu_outgoing_write, ex2).is_err());
    }
}
