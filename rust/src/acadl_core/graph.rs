//! The architecture graph (AG): the UML object diagram describing one
//! computer architecture (§4), with edge-validity enforcement and the
//! pre-resolved adjacency queries the simulator's hot loop relies on.

use std::collections::HashMap;

use thiserror::Error;

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::{check_edge, Edge, EdgeError, EdgeKind};
use crate::acadl_core::object::{Object, ObjectKind};

/// Dense object handle into [`Ag::objects`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjId(pub u32);

impl ObjId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Dense register handle: registers of all RegisterFiles, interned.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegId(pub u32);

impl RegId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

#[derive(Debug, Error)]
pub enum AgError {
    #[error("duplicate object name `{0}`")]
    DuplicateName(String),
    #[error("duplicate register name `{0}` (registers are global identifiers)")]
    DuplicateRegister(String),
    #[error("unknown object `{0}`")]
    UnknownObject(String),
    #[error(transparent)]
    Edge(#[from] EdgeError),
    #[error("graph validation: {0}")]
    Invalid(String),
}

/// The architecture graph: objects + typed edges + the global register
/// namespace (the paper's registers are unique names; we intern them to
/// dense [`RegId`]s so simulator state is flat arrays, not hash maps).
#[derive(Debug, Clone, Default)]
pub struct Ag {
    pub objects: Vec<Object>,
    pub edges: Vec<Edge>,
    by_name: HashMap<String, ObjId>,
    /// reg name -> id
    reg_by_name: HashMap<String, RegId>,
    /// reg id -> (owning RF, index within RF, name, initial value)
    regs: Vec<RegInfo>,
}

#[derive(Debug, Clone)]
pub struct RegInfo {
    pub rf: ObjId,
    pub index_in_rf: u32,
    pub name: String,
    pub init: Data,
}

impl Ag {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an object; register-file registers join the global namespace.
    pub fn add(&mut self, obj: Object) -> Result<ObjId, AgError> {
        if self.by_name.contains_key(&obj.name) {
            return Err(AgError::DuplicateName(obj.name));
        }
        let id = ObjId(self.objects.len() as u32);
        if let ObjectKind::RegisterFile(rf) = &obj.kind {
            for (i, (reg_name, init)) in rf.registers.iter().enumerate() {
                if self.reg_by_name.contains_key(reg_name) {
                    return Err(AgError::DuplicateRegister(reg_name.clone()));
                }
                let rid = RegId(self.regs.len() as u32);
                self.reg_by_name.insert(reg_name.clone(), rid);
                self.regs.push(RegInfo {
                    rf: id,
                    index_in_rf: i as u32,
                    name: reg_name.clone(),
                    init: init.clone(),
                });
            }
        }
        self.by_name.insert(obj.name.clone(), id);
        self.objects.push(obj);
        Ok(id)
    }

    /// Add a validated edge (the `@generate` check of Listing 1).
    pub fn connect(&mut self, src: ObjId, dst: ObjId, kind: EdgeKind) -> Result<(), AgError> {
        let s = &self.objects[src.idx()];
        let d = &self.objects[dst.idx()];
        check_edge(kind, (&s.name, &s.kind), (&d.name, &d.kind))?;
        self.edges.push(Edge { src, dst, kind });
        Ok(())
    }

    // ------------------------------------------------------------ lookups

    pub fn id(&self, name: &str) -> Option<ObjId> {
        self.by_name.get(name).copied()
    }

    pub fn obj(&self, id: ObjId) -> &Object {
        &self.objects[id.idx()]
    }

    pub fn kind(&self, id: ObjId) -> &ObjectKind {
        &self.objects[id.idx()].kind
    }

    pub fn name(&self, id: ObjId) -> &str {
        &self.objects[id.idx()].name
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }

    // ------------------------------------------------------- registers

    pub fn reg_id(&self, name: &str) -> Option<RegId> {
        self.reg_by_name.get(name).copied()
    }

    pub fn reg(&self, id: RegId) -> &RegInfo {
        &self.regs[id.idx()]
    }

    pub fn reg_count(&self) -> usize {
        self.regs.len()
    }

    pub fn regs(&self) -> &[RegInfo] {
        &self.regs
    }

    // ------------------------------------------------------ adjacency

    pub fn edges_from(&self, id: ObjId, kind: EdgeKind) -> impl Iterator<Item = ObjId> + '_ {
        self.edges
            .iter()
            .filter(move |e| e.src == id && e.kind == kind)
            .map(|e| e.dst)
    }

    pub fn edges_to(&self, id: ObjId, kind: EdgeKind) -> impl Iterator<Item = ObjId> + '_ {
        self.edges
            .iter()
            .filter(move |e| e.dst == id && e.kind == kind)
            .map(|e| e.src)
    }

    /// FunctionalUnits contained by an execute stage.
    pub fn contained_fus(&self, es: ObjId) -> Vec<ObjId> {
        self.edges_from(es, EdgeKind::Contains).collect()
    }

    /// Pipeline stages this stage can forward to.
    pub fn forward_targets(&self, ps: ObjId) -> Vec<ObjId> {
        self.edges_from(ps, EdgeKind::Forward).collect()
    }

    /// RegisterFiles a functional unit may read (READ_DATA rf -> fu).
    pub fn readable_rfs(&self, fu: ObjId) -> Vec<ObjId> {
        self.edges_to(fu, EdgeKind::ReadData)
            .filter(|&o| self.kind(o).is_register_file())
            .collect()
    }

    /// RegisterFiles a functional unit may write (WRITE_DATA fu -> rf).
    pub fn writable_rfs(&self, fu: ObjId) -> Vec<ObjId> {
        self.edges_from(fu, EdgeKind::WriteData)
            .filter(|&o| self.kind(o).is_register_file())
            .collect()
    }

    /// DataStorages reachable from a memory access unit (either direction:
    /// READ_DATA storage -> mau, or WRITE_DATA mau -> storage).
    pub fn storages_of_mau(&self, mau: ObjId) -> Vec<ObjId> {
        let mut v: Vec<ObjId> = self
            .edges_to(mau, EdgeKind::ReadData)
            .filter(|&o| self.kind(o).is_data_storage())
            .chain(
                self.edges_from(mau, EdgeKind::WriteData)
                    .filter(|&o| self.kind(o).is_data_storage()),
            )
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// The backing store of a cache (WRITE_DATA cache -> storage, or
    /// READ_DATA storage -> cache).
    pub fn backing_of(&self, cache: ObjId) -> Option<ObjId> {
        self.edges_from(cache, EdgeKind::WriteData)
            .chain(self.edges_to(cache, EdgeKind::ReadData))
            .find(|&o| self.kind(o).is_data_storage() && o != cache)
    }

    /// All InstructionFetchStage objects (a model may have several
    /// independent fetch front-ends).
    pub fn fetch_stages(&self) -> Vec<ObjId> {
        (0..self.objects.len() as u32)
            .map(ObjId)
            .filter(|&o| {
                matches!(self.kind(o), ObjectKind::InstructionFetchStage(_))
            })
            .collect()
    }

    /// The instruction memory of a fetch stage: the storage its contained
    /// InstructionMemoryAccessUnit reads.
    pub fn instruction_memory(&self, ifs: ObjId) -> Option<ObjId> {
        let imau = self
            .contained_fus(ifs)
            .into_iter()
            .find(|&f| {
                matches!(self.kind(f), ObjectKind::InstructionMemoryAccessUnit(_))
            })?;
        self.edges_to(imau, EdgeKind::ReadData)
            .find(|&o| self.kind(o).is_data_storage())
    }

    /// Does `addr` fall in a MemoryInterface's served range?  Caches accept
    /// any address their backing chain accepts.
    pub fn storage_accepts(&self, storage: ObjId, addr: u64) -> bool {
        match self.kind(storage) {
            k if k.is_memory_interface() => {
                let (lo, hi) = k.address_range().unwrap();
                (lo..hi).contains(&addr)
            }
            ObjectKind::Cache(_) => self
                .backing_of(storage)
                .is_some_and(|b| self.storage_accepts(b, addr)),
            _ => false,
        }
    }

    // ------------------------------------------------------ validation

    /// Whole-graph validation beyond per-edge checks (the rest of the
    /// `@generate` contract): structural invariants every simulatable AG
    /// must satisfy.
    pub fn validate(&self) -> Result<(), AgError> {
        for ifs in self.fetch_stages() {
            let imaus: Vec<_> = self
                .contained_fus(ifs)
                .into_iter()
                .filter(|&f| {
                    matches!(self.kind(f), ObjectKind::InstructionMemoryAccessUnit(_))
                })
                .collect();
            if imaus.len() != 1 {
                return Err(AgError::Invalid(format!(
                    "fetch stage `{}` must contain exactly one InstructionMemoryAccessUnit (found {})",
                    self.name(ifs),
                    imaus.len()
                )));
            }
            if self.instruction_memory(ifs).is_none() {
                return Err(AgError::Invalid(format!(
                    "fetch stage `{}` has no instruction memory (READ_DATA storage -> imau missing)",
                    self.name(ifs)
                )));
            }
        }
        // Every non-IMAU functional unit must be contained by exactly one
        // execute stage, otherwise it can never receive instructions.
        for id in (0..self.objects.len() as u32).map(ObjId) {
            let k = self.kind(id);
            if k.is_functional_unit() {
                let parents = self
                    .edges_to(id, EdgeKind::Contains)
                    .count();
                if parents != 1 {
                    return Err(AgError::Invalid(format!(
                        "functional unit `{}` contained by {} execute stages (need exactly 1)",
                        self.name(id),
                        parents
                    )));
                }
            }
        }
        // Caches must have a backing store.
        for id in (0..self.objects.len() as u32).map(ObjId) {
            if self.kind(id).is_cache() && self.backing_of(id).is_none() {
                return Err(AgError::Invalid(format!(
                    "cache `{}` has no backing store",
                    self.name(id)
                )));
            }
        }
        // Port-count budget: storages may not have more MAUs attached than
        // `read_write_ports`.
        for id in (0..self.objects.len() as u32).map(ObjId) {
            if let Some(p) = self.kind(id).storage_params() {
                let maus = self
                    .edges_to(id, EdgeKind::WriteData)
                    .chain(self.edges_from(id, EdgeKind::ReadData))
                    .filter(|&o| self.kind(o).is_memory_access_unit())
                    .collect::<std::collections::BTreeSet<_>>()
                    .len();
                if maus > p.read_write_ports {
                    return Err(AgError::Invalid(format!(
                        "storage `{}` has {} MAUs attached but only {} ports",
                        self.name(id),
                        maus,
                        p.read_write_ports
                    )));
                }
            }
        }
        Ok(())
    }

    /// Graph statistics line for the CLI's `validate` subcommand.
    pub fn summary(&self) -> String {
        let mut counts: HashMap<&'static str, usize> = HashMap::new();
        for o in &self.objects {
            *counts.entry(o.kind.class_name()).or_default() += 1;
        }
        let mut pairs: Vec<_> = counts.into_iter().collect();
        pairs.sort();
        let classes = pairs
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        format!(
            "{} objects, {} edges, {} registers | {}",
            self.objects.len(),
            self.edges.len(),
            self.regs.len(),
            classes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::latency::Latency;
    use crate::acadl_core::object::build;

    fn tiny_ag() -> (Ag, ObjId, ObjId, ObjId) {
        let mut ag = Ag::new();
        let ex = ag.add(build::execute_stage("ex0", 1)).unwrap();
        let fu = ag
            .add(build::functional_unit("fu0", &["add"], Latency::Const(1)))
            .unwrap();
        let rf = ag
            .add(build::register_file(
                "rf0",
                32,
                vec![
                    ("r0".into(), Data::int(32, 0)),
                    ("r1".into(), Data::int(32, 7)),
                ],
            ))
            .unwrap();
        ag.connect(ex, fu, EdgeKind::Contains).unwrap();
        ag.connect(rf, fu, EdgeKind::ReadData).unwrap();
        ag.connect(fu, rf, EdgeKind::WriteData).unwrap();
        (ag, ex, fu, rf)
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut ag = Ag::new();
        ag.add(build::execute_stage("x", 1)).unwrap();
        assert!(matches!(
            ag.add(build::execute_stage("x", 1)),
            Err(AgError::DuplicateName(_))
        ));
    }

    #[test]
    fn duplicate_registers_rejected() {
        let mut ag = Ag::new();
        ag.add(build::register_file(
            "a",
            32,
            vec![("r0".into(), Data::int(32, 0))],
        ))
        .unwrap();
        assert!(matches!(
            ag.add(build::register_file(
                "b",
                32,
                vec![("r0".into(), Data::int(32, 0))],
            )),
            Err(AgError::DuplicateRegister(_))
        ));
    }

    #[test]
    fn register_interning() {
        let (ag, _, _, rf) = tiny_ag();
        let r1 = ag.reg_id("r1").unwrap();
        assert_eq!(ag.reg(r1).rf, rf);
        assert_eq!(ag.reg(r1).index_in_rf, 1);
        assert_eq!(ag.reg(r1).init.payload.as_int(), 7);
        assert_eq!(ag.reg_count(), 2);
        assert!(ag.reg_id("r9").is_none());
    }

    #[test]
    fn adjacency_queries() {
        let (ag, ex, fu, rf) = tiny_ag();
        assert_eq!(ag.contained_fus(ex), vec![fu]);
        assert_eq!(ag.readable_rfs(fu), vec![rf]);
        assert_eq!(ag.writable_rfs(fu), vec![rf]);
        assert!(ag.forward_targets(ex).is_empty());
    }

    #[test]
    fn invalid_edge_rejected_by_connect() {
        let (mut ag, ex, _fu, rf) = tiny_ag();
        let err = ag.connect(rf, ex, EdgeKind::Forward).unwrap_err();
        assert!(err.to_string().contains("FORWARD"));
    }

    #[test]
    fn validate_catches_orphan_fu() {
        let mut ag = Ag::new();
        ag.add(build::functional_unit("fu0", &["add"], Latency::Const(1)))
            .unwrap();
        assert!(ag.validate().is_err());
    }

    #[test]
    fn validate_catches_cache_without_backing() {
        let mut ag = Ag::new();
        ag.add(crate::arch::parts::cache_default("c0")).unwrap();
        assert!(ag.validate().is_err());
    }

    #[test]
    fn summary_counts_classes() {
        let (ag, ..) = tiny_ag();
        let s = ag.summary();
        assert!(s.contains("ExecuteStage=1"), "{s}");
        assert!(s.contains("2 registers"), "{s}");
    }
}
