//! The ACADL language core: objects, typed edges, architecture graphs,
//! templates with dangling edges, and latency expressions.
//!
//! This is the Rust equivalent of the paper's C++ core + Python front-end
//! (§3–§4): twelve classes, two interfaces, and one virtual base class
//! (Fig. 1) are modeled as [`object::ObjectKind`] variants; the class
//! hierarchy (e.g. `ExecuteStage : PipelineStage`) is exposed through `is_*`
//! predicate methods used by the edge-validity rules in [`edge`].

pub mod data;
pub mod edge;
pub mod graph;
pub mod latency;
pub mod object;
pub mod template;

pub use data::{Data, Value};
pub use edge::{Edge, EdgeKind};
pub use graph::{Ag, AgError, ObjId};
pub use latency::Latency;
pub use object::{Object, ObjectKind};
