//! `Data`: values stored in memories, registers, and instruction immediates.
//!
//! The paper (§3): *"Data represents any data stored in memories, registers,
//! and immediate values of instructions. `size` is the data size in bits.
//! `payload` is the data itself, which is used for the functional
//! simulation."*
//!
//! The union ISA of the three modeled accelerators needs three payload
//! shapes: scalar integers (OMA address/loop registers), scalar floats
//! (OMA MAC data path), and short float vectors (Γ̈'s 128-bit vector
//! registers holding 8×16-bit rows — we model numerics in f32, see
//! DESIGN.md substitution table).

use std::fmt;

/// A typed payload value for functional simulation.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Scalar integer (addresses, loop counters, the `pc`).
    Int(i64),
    /// Scalar float (the OMA MAC data path).
    F32(f32),
    /// Short vector (one Γ̈ vector register = one matrix row).
    Vec(Box<[f32]>),
}

/// The discriminant of a [`Value`] without its payload.  The simulator's
/// register file stores scalar payloads as untagged 64-bit words next to a
/// dense tag array (see `sim::exec::RegState`), so the hot scalar ALU path
/// branches on a one-byte tag instead of matching (and cloning) a full
/// `Value`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ValueTag {
    Int,
    F32,
    Vec,
}

impl Value {
    /// This value's tag (payload-free discriminant).
    #[inline]
    pub fn tag(&self) -> ValueTag {
        match self {
            Value::Int(_) => ValueTag::Int,
            Value::F32(_) => ValueTag::F32,
            Value::Vec(_) => ValueTag::Vec,
        }
    }
}

impl Value {
    pub fn zero_int() -> Self {
        Value::Int(0)
    }

    pub fn zero_f32() -> Self {
        Value::F32(0.0)
    }

    pub fn zero_vec(len: usize) -> Self {
        Value::Vec(vec![0.0; len].into_boxed_slice())
    }

    /// Integer view; floats truncate (used for address arithmetic on
    /// registers the program also uses as data — matches a real datapath
    /// reinterpreting bits is *not* modeled; conversion is by value).
    pub fn as_int(&self) -> i64 {
        match self {
            Value::Int(v) => *v,
            Value::F32(v) => *v as i64,
            Value::Vec(_) => 0,
        }
    }

    pub fn as_f32(&self) -> f32 {
        match self {
            Value::Int(v) => *v as f32,
            Value::F32(v) => *v,
            Value::Vec(v) => v.first().copied().unwrap_or(0.0),
        }
    }

    pub fn as_slice(&self) -> &[f32] {
        match self {
            Value::Vec(v) => v,
            _ => &[],
        }
    }

    /// Bit width of a canonical encoding of this value (diagnostics only).
    pub fn nominal_bits(&self) -> u32 {
        match self {
            Value::Int(_) => 64,
            Value::F32(_) => 32,
            Value::Vec(v) => (v.len() * 32) as u32,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::F32(v) => write!(f, "{v}"),
            Value::Vec(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// `Data` object: size in bits plus the payload (Fig. 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Data {
    /// Data size in bits.
    pub size: u32,
    /// Payload used by the functional simulation.
    pub payload: Value,
}

impl Data {
    pub fn new(size: u32, payload: Value) -> Self {
        Data { size, payload }
    }

    /// A `size`-bit integer datum (the paper's `Data(32, 0)` style).
    pub fn int(size: u32, v: i64) -> Self {
        Data::new(size, Value::Int(v))
    }

    pub fn f32(v: f32) -> Self {
        Data::new(32, Value::F32(v))
    }

    /// A vector datum of `len` f32 lanes (Γ̈ vector registers: the paper's
    /// 128-bit / 8×int16 design point keeps `size = 128`).
    pub fn vec(size: u32, len: usize) -> Self {
        Data::new(size, Value::zero_vec(len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags() {
        assert_eq!(Value::Int(3).tag(), ValueTag::Int);
        assert_eq!(Value::F32(1.5).tag(), ValueTag::F32);
        assert_eq!(Value::zero_vec(2).tag(), ValueTag::Vec);
    }

    #[test]
    fn conversions() {
        assert_eq!(Value::Int(7).as_f32(), 7.0);
        assert_eq!(Value::F32(3.9).as_int(), 3);
        assert_eq!(Value::zero_vec(4).as_slice(), &[0.0; 4]);
        assert_eq!(Value::Int(1).as_slice(), &[] as &[f32]);
    }

    #[test]
    fn constructors() {
        let d = Data::int(32, 5);
        assert_eq!(d.size, 32);
        assert_eq!(d.payload.as_int(), 5);
        let v = Data::vec(128, 8);
        assert_eq!(v.payload.as_slice().len(), 8);
        assert_eq!(v.size, 128);
    }

    #[test]
    fn display() {
        assert_eq!(Value::Int(3).to_string(), "3");
        assert_eq!(Value::Vec(vec![1.0, 2.0].into()).to_string(), "[1, 2]");
    }
}
