//! Latency values: constant cycle counts or expressions evaluated during
//! performance estimation.
//!
//! The paper (§3): *"latency describes a time delta in clock cycles. It can
//! be specified as an integer value or a string containing a function that
//! is evaluated during the performance estimation."*  We implement the
//! string form as a small arithmetic expression language over named
//! variables (e.g. `"4 + size / 16"`), parsed once at model-build time and
//! evaluated cheaply (no allocation) inside the simulation loop.
//!
//! Grammar (integer arithmetic, C precedence):
//! ```text
//! expr   := term (('+'|'-') term)*
//! term   := factor (('*'|'/'|'%') factor)*
//! factor := NUMBER | IDENT | IDENT '(' expr (',' expr)* ')' | '(' expr ')' | '-' factor
//! ```
//! Built-in functions: `min`, `max`, `ceil_div`, `log2` (ceil), `pow2`.

use std::collections::HashMap;
use std::fmt;

use thiserror::Error;

/// A latency in clock cycles: constant, or an expression over context
/// variables supplied by the evaluating hardware object.
#[derive(Debug, Clone, PartialEq)]
pub enum Latency {
    /// Fixed number of clock cycles.
    Const(u64),
    /// Compiled expression, evaluated against a [`LatencyCtx`].
    Expr(Expr),
}

impl Latency {
    /// Parse either an integer literal or an expression string.
    pub fn parse(s: &str) -> Result<Self, LatencyError> {
        let t = s.trim();
        if let Ok(v) = t.parse::<u64>() {
            return Ok(Latency::Const(v));
        }
        Ok(Latency::Expr(Expr::parse(t)?))
    }

    /// Evaluate with an empty context; errors if variables are referenced.
    pub fn eval_const(&self) -> Result<u64, LatencyError> {
        self.eval(&LatencyCtx::default())
    }

    /// The statically resolved latency horizon, if there is one: the exact
    /// number of cycles after which a unit evaluating this latency changes
    /// state.  `Const` latencies have a fixed horizon; expression
    /// latencies resolve per dispatch (context-dependent) and return
    /// `None`.  The simulation kernel uses this to pre-resolve functional
    /// unit completion times and the event-driven backend to schedule
    /// them without polling.
    pub fn const_horizon(&self) -> Option<u64> {
        match self {
            Latency::Const(v) => Some(*v),
            Latency::Expr(_) => None,
        }
    }

    /// Evaluate against `ctx`. Division by zero and unknown variables error.
    pub fn eval(&self, ctx: &LatencyCtx) -> Result<u64, LatencyError> {
        match self {
            Latency::Const(v) => Ok(*v),
            Latency::Expr(e) => {
                let v = e.eval(ctx)?;
                u64::try_from(v).map_err(|_| LatencyError::Negative(v))
            }
        }
    }
}

impl From<u64> for Latency {
    fn from(v: u64) -> Self {
        Latency::Const(v)
    }
}

impl fmt::Display for Latency {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Latency::Const(v) => write!(f, "{v}"),
            Latency::Expr(e) => write!(f, "{}", e.src),
        }
    }
}

/// Variable bindings for expression evaluation (e.g. `size`, `rows`).
#[derive(Debug, Clone, Default)]
pub struct LatencyCtx {
    vars: HashMap<String, i64>,
}

impl LatencyCtx {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with(mut self, name: &str, value: i64) -> Self {
        self.vars.insert(name.to_string(), value);
        self
    }

    pub fn set(&mut self, name: &str, value: i64) {
        self.vars.insert(name.to_string(), value);
    }

    pub fn get(&self, name: &str) -> Option<i64> {
        self.vars.get(name).copied()
    }
}

#[derive(Debug, Error, Clone, PartialEq)]
pub enum LatencyError {
    #[error("latency parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("unknown variable `{0}` in latency expression")]
    UnknownVar(String),
    #[error("unknown function `{0}` in latency expression")]
    UnknownFn(String),
    #[error("wrong arity for `{0}`: expected {1}, got {2}")]
    Arity(String, usize, usize),
    #[error("division by zero in latency expression")]
    DivZero,
    #[error("latency evaluated to negative value {0}")]
    Negative(i64),
}

/// A compiled latency expression (postfix program, allocation-free eval via
/// a caller-scratch stack would be possible; a small Vec is fine off the
/// inner loop — FU latencies are evaluated once per dispatched instruction).
#[derive(Debug, Clone, PartialEq)]
pub struct Expr {
    src: String,
    code: Vec<Op>,
}

#[derive(Debug, Clone, PartialEq)]
enum Op {
    Push(i64),
    Var(String),
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Neg,
    Min,
    Max,
    CeilDiv,
    Log2,
    Pow2,
}

impl Expr {
    pub fn parse(src: &str) -> Result<Self, LatencyError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
            code: Vec::new(),
        };
        p.expr()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(LatencyError::Parse(p.pos, "trailing input".into()));
        }
        Ok(Expr {
            src: src.to_string(),
            code: p.code,
        })
    }

    pub fn eval(&self, ctx: &LatencyCtx) -> Result<i64, LatencyError> {
        let mut stack: Vec<i64> = Vec::with_capacity(8);
        for op in &self.code {
            match op {
                Op::Push(v) => stack.push(*v),
                Op::Var(name) => stack.push(
                    ctx.get(name)
                        .ok_or_else(|| LatencyError::UnknownVar(name.clone()))?,
                ),
                Op::Neg => {
                    let a = stack.pop().unwrap();
                    stack.push(-a);
                }
                Op::Log2 => {
                    // ceil(log2(a)), with a clamped to >= 1.
                    let a = stack.pop().unwrap().max(1) as u64;
                    let v = if a <= 1 { 0 } else { 64 - (a - 1).leading_zeros() as i64 };
                    stack.push(v);
                }
                Op::Pow2 => {
                    let a = stack.pop().unwrap().clamp(0, 62);
                    stack.push(1i64 << a);
                }
                binop => {
                    let b = stack.pop().unwrap();
                    let a = stack.pop().unwrap();
                    let v = match binop {
                        Op::Add => a.wrapping_add(b),
                        Op::Sub => a.wrapping_sub(b),
                        Op::Mul => a.wrapping_mul(b),
                        Op::Div => {
                            if b == 0 {
                                return Err(LatencyError::DivZero);
                            }
                            a / b
                        }
                        Op::Rem => {
                            if b == 0 {
                                return Err(LatencyError::DivZero);
                            }
                            a % b
                        }
                        Op::Min => a.min(b),
                        Op::Max => a.max(b),
                        Op::CeilDiv => {
                            if b == 0 {
                                return Err(LatencyError::DivZero);
                            }
                            (a + b - 1) / b
                        }
                        _ => unreachable!(),
                    };
                    stack.push(v);
                }
            }
        }
        debug_assert_eq!(stack.len(), 1);
        Ok(stack.pop().unwrap())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    code: Vec<Op>,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expr(&mut self) -> Result<(), LatencyError> {
        self.term()?;
        while let Some(c) = self.peek() {
            match c {
                b'+' => {
                    self.pos += 1;
                    self.term()?;
                    self.code.push(Op::Add);
                }
                b'-' => {
                    self.pos += 1;
                    self.term()?;
                    self.code.push(Op::Sub);
                }
                _ => break,
            }
        }
        Ok(())
    }

    fn term(&mut self) -> Result<(), LatencyError> {
        self.factor()?;
        while let Some(c) = self.peek() {
            match c {
                b'*' => {
                    self.pos += 1;
                    self.factor()?;
                    self.code.push(Op::Mul);
                }
                b'/' => {
                    self.pos += 1;
                    self.factor()?;
                    self.code.push(Op::Div);
                }
                b'%' => {
                    self.pos += 1;
                    self.factor()?;
                    self.code.push(Op::Rem);
                }
                _ => break,
            }
        }
        Ok(())
    }

    fn factor(&mut self) -> Result<(), LatencyError> {
        match self.peek() {
            Some(b'-') => {
                self.pos += 1;
                self.factor()?;
                self.code.push(Op::Neg);
                Ok(())
            }
            Some(b'(') => {
                self.pos += 1;
                self.expr()?;
                self.expect(b')')
            }
            Some(c) if c.is_ascii_digit() => {
                let start = self.pos;
                while self
                    .bytes
                    .get(self.pos)
                    .is_some_and(|b| b.is_ascii_digit())
                {
                    self.pos += 1;
                }
                let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
                let v: i64 = text
                    .parse()
                    .map_err(|_| LatencyError::Parse(start, "bad number".into()))?;
                self.code.push(Op::Push(v));
                Ok(())
            }
            Some(c) if c.is_ascii_alphabetic() || c == b'_' => {
                let start = self.pos;
                while self
                    .bytes
                    .get(self.pos)
                    .is_some_and(|b| b.is_ascii_alphanumeric() || *b == b'_')
                {
                    self.pos += 1;
                }
                let name =
                    std::str::from_utf8(&self.bytes[start..self.pos]).unwrap().to_string();
                if self.peek() == Some(b'(') {
                    self.pos += 1;
                    let mut argc = 0usize;
                    if self.peek() != Some(b')') {
                        loop {
                            self.expr()?;
                            argc += 1;
                            match self.peek() {
                                Some(b',') => {
                                    self.pos += 1;
                                }
                                _ => break,
                            }
                        }
                    }
                    self.expect(b')')?;
                    let (op, arity) = match name.as_str() {
                        "min" => (Op::Min, 2),
                        "max" => (Op::Max, 2),
                        "ceil_div" => (Op::CeilDiv, 2),
                        "log2" => (Op::Log2, 1),
                        "pow2" => (Op::Pow2, 1),
                        _ => return Err(LatencyError::UnknownFn(name)),
                    };
                    if argc != arity {
                        return Err(LatencyError::Arity(name, arity, argc));
                    }
                    self.code.push(op);
                } else {
                    self.code.push(Op::Var(name));
                }
                Ok(())
            }
            _ => Err(LatencyError::Parse(self.pos, "expected factor".into())),
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), LatencyError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(LatencyError::Parse(self.pos, format!("expected `{}`", c as char)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(src: &str, ctx: &LatencyCtx) -> i64 {
        Expr::parse(src).unwrap().eval(ctx).unwrap()
    }

    #[test]
    fn constants() {
        assert_eq!(Latency::parse("7").unwrap(), Latency::Const(7));
        assert_eq!(Latency::parse(" 42 ").unwrap().eval_const().unwrap(), 42);
    }

    #[test]
    fn const_horizon_resolves_only_constants() {
        assert_eq!(Latency::Const(9).const_horizon(), Some(9));
        let l = Latency::parse("4 + size / 16").unwrap();
        assert_eq!(l.const_horizon(), None);
    }

    #[test]
    fn arithmetic_precedence() {
        let ctx = LatencyCtx::default();
        assert_eq!(eval("1 + 2 * 3", &ctx), 7);
        assert_eq!(eval("(1 + 2) * 3", &ctx), 9);
        assert_eq!(eval("10 / 3", &ctx), 3);
        assert_eq!(eval("10 % 3", &ctx), 1);
        assert_eq!(eval("-4 + 10", &ctx), 6);
    }

    #[test]
    fn variables() {
        let ctx = LatencyCtx::new().with("size", 64).with("width", 16);
        assert_eq!(eval("4 + size / width", &ctx), 8);
        assert_eq!(
            Expr::parse("missing + 1").unwrap().eval(&ctx),
            Err(LatencyError::UnknownVar("missing".into()))
        );
    }

    #[test]
    fn functions() {
        let ctx = LatencyCtx::new().with("n", 100);
        assert_eq!(eval("min(3, 5)", &ctx), 3);
        assert_eq!(eval("max(3, 5)", &ctx), 5);
        assert_eq!(eval("ceil_div(n, 32)", &ctx), 4);
        assert_eq!(eval("pow2(4)", &ctx), 16);
        assert_eq!(eval("log2(8)", &ctx), 3);
        assert_eq!(eval("log2(9)", &ctx), 4);
        assert_eq!(eval("log2(1)", &ctx), 0);
    }

    #[test]
    fn errors() {
        assert!(Expr::parse("1 +").is_err());
        assert!(Expr::parse("foo(1)").is_err());
        assert!(Expr::parse("min(1)").is_err());
        assert_eq!(
            Expr::parse("1/0").unwrap().eval(&LatencyCtx::default()),
            Err(LatencyError::DivZero)
        );
        // Negative result rejected at the Latency level.
        let l = Latency::parse("2 - 5").unwrap();
        assert!(matches!(l.eval_const(), Err(LatencyError::Negative(-3))));
    }

    #[test]
    fn display_roundtrip() {
        let l = Latency::parse("4 + size / 16").unwrap();
        assert_eq!(l.to_string(), "4 + size / 16");
        assert_eq!(Latency::Const(3).to_string(), "3");
    }
}
