//! Opcodes of the union ISA, with their mnemonics and structural metadata.

use std::fmt;
use std::str::FromStr;

/// Every operation used by the OMA (§4.1/§5), the systolic array (§4.2),
/// and Γ̈ (§4.3) models.  Kept ≤ 64 variants so FU capability sets compile
/// to a single `u64` mask in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    // --- scalar control/data (OMA, Listing 5) ---
    Nop,
    Halt,
    /// reg -> reg copy.
    Mov,
    /// immediate -> reg.
    Movi,
    Add,
    Addi,
    Sub,
    Subi,
    Mul,
    Muli,
    /// Multiply-accumulate: acc += a * b (the OMA's built-in MAC).
    Mac,
    // --- scalar reduction/activation (transformer row-wise operators) ---
    /// f32 divide: a / b (softmax normalization, layer-norm mean).
    Div,
    /// Scalar max (streaming max-reduction for stable softmax).
    Max,
    /// f32 exponential.
    Exp,
    /// f32 reciprocal square root: 1 / sqrt(a) (layer-norm denominator).
    Rsqrt,
    /// f32 GELU activation (tanh approximation).
    Gelu,
    /// Memory read into a register (scalar or vector by dest width).
    Load,
    /// Register into memory.
    Store,
    /// Branch if equal: if a == b then pc := self + offset.
    Beqi,
    /// Branch if not equal.
    Bnei,
    /// Unconditional relative jump: pc := self + offset.
    Jumpi,
    // --- tensor (vector registers) ---
    /// Lane-wise vector add.
    VAdd,
    /// Lane-wise vector multiply.
    VMul,
    /// Lane-wise ReLU.
    VRelu,
    /// Lane-wise max (2×1 max-pool building block).
    VMaxp,
    /// Systolic PE step: acc += a_in * b_in, then forward a_in right and
    /// b_in down (writes into neighbor register files).
    MacFwd,
    // --- fused tensor (Γ̈, Listing 4) ---
    /// 8×8 GeMM over register groups, optional activation (imm 1 = ReLU).
    Gemm,
}

impl Opcode {
    pub const COUNT: usize = 27;

    /// Assembly mnemonic (the string stored in FU `to_process` sets).
    pub const fn mnemonic(self) -> &'static str {
        match self {
            Opcode::Nop => "nop",
            Opcode::Halt => "halt",
            Opcode::Mov => "mov",
            Opcode::Movi => "movi",
            Opcode::Add => "add",
            Opcode::Addi => "addi",
            Opcode::Sub => "sub",
            Opcode::Subi => "subi",
            Opcode::Mul => "mul",
            Opcode::Muli => "muli",
            Opcode::Mac => "mac",
            Opcode::Div => "div",
            Opcode::Max => "max",
            Opcode::Exp => "exp",
            Opcode::Rsqrt => "rsqrt",
            Opcode::Gelu => "gelu",
            Opcode::Load => "load",
            Opcode::Store => "store",
            Opcode::Beqi => "beqi",
            Opcode::Bnei => "bnei",
            Opcode::Jumpi => "jumpi",
            Opcode::VAdd => "vadd",
            Opcode::VMul => "vmul",
            Opcode::VRelu => "vrelu",
            Opcode::VMaxp => "vmaxp",
            Opcode::MacFwd => "macf",
            Opcode::Gemm => "gemm",
        }
    }

    /// Dense index for capability bitmasks.
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Does this opcode read or write memory (i.e. must a
    /// `MemoryAccessUnit` process it)?
    pub const fn is_memory(self) -> bool {
        matches!(self, Opcode::Load | Opcode::Store)
    }

    /// Does this opcode write the program counter (fetch must stall while
    /// one is in flight — §6 control-hazard handling)?
    pub const fn is_control(self) -> bool {
        matches!(self, Opcode::Beqi | Opcode::Bnei | Opcode::Jumpi | Opcode::Halt)
    }

    pub fn all() -> impl Iterator<Item = Opcode> {
        const ALL: [Opcode; Opcode::COUNT] = [
            Opcode::Nop,
            Opcode::Halt,
            Opcode::Mov,
            Opcode::Movi,
            Opcode::Add,
            Opcode::Addi,
            Opcode::Sub,
            Opcode::Subi,
            Opcode::Mul,
            Opcode::Muli,
            Opcode::Mac,
            Opcode::Div,
            Opcode::Max,
            Opcode::Exp,
            Opcode::Rsqrt,
            Opcode::Gelu,
            Opcode::Load,
            Opcode::Store,
            Opcode::Beqi,
            Opcode::Bnei,
            Opcode::Jumpi,
            Opcode::VAdd,
            Opcode::VMul,
            Opcode::VRelu,
            Opcode::VMaxp,
            Opcode::MacFwd,
            Opcode::Gemm,
        ];
        ALL.into_iter()
    }
}

impl fmt::Display for Opcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.mnemonic())
    }
}

impl FromStr for Opcode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Opcode::all()
            .find(|o| o.mnemonic() == s)
            .ok_or_else(|| format!("unknown mnemonic `{s}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnemonic_roundtrip() {
        for op in Opcode::all() {
            assert_eq!(op.mnemonic().parse::<Opcode>().unwrap(), op);
        }
    }

    #[test]
    fn indices_fit_u64_mask() {
        for op in Opcode::all() {
            assert!(op.index() < 64);
        }
    }

    #[test]
    fn classification() {
        assert!(Opcode::Load.is_memory());
        assert!(!Opcode::Mac.is_memory());
        assert!(Opcode::Beqi.is_control());
        assert!(Opcode::Halt.is_control());
        assert!(!Opcode::Gemm.is_control());
    }

    #[test]
    fn count_matches_all() {
        assert_eq!(Opcode::all().count(), Opcode::COUNT);
    }
}
