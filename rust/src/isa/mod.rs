//! The instruction set: the union ISA of the paper's three accelerators and
//! a two-pass assembler for the listing syntax of §4.3/§5.
//!
//! ACADL instructions are not limited to fine-grained operations — §3: *"An
//! instruction can also carry out complex operations like matrix-matrix
//! multiplication"*.  The [`opcode::Opcode`] enum therefore spans three
//! abstraction levels:
//!
//! * **scalar** (OMA, systolic PEs): `mov addi mac load store beqi jumpi …`
//! * **tensor** (vector registers):  `vadd vmul vrelu vmaxp`
//! * **fused tensor** (Γ̈):           `gemm` (8×8 matmul + optional ReLU)
//!
//! Which unit executes a mnemonic is *not* the ISA's business — routing is
//! decided by each `FunctionalUnit`'s `to_process` set and register
//! accessibility, exactly as in the paper.

pub mod assembler;
pub mod instruction;
pub mod opcode;
pub mod program;

pub use assembler::{assemble, AsmError};
pub use instruction::{AddrRef, Instruction};
pub use opcode::Opcode;
pub use program::Program;

/// The Γ̈ fused-tensor tile dimension (§4.3: 8×8 matrices in vector regs).
pub const GAMMA_TILE: usize = 8;

/// Nominal instruction encoding width in bytes (pc arithmetic, Listing 5's
/// `#-28`-style byte offsets, and instruction-memory layout).
pub const INSTR_BYTES: u64 = 4;
