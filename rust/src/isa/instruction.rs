//! The ACADL `Instruction` class (§3): accessed registers, memory
//! addresses, immediates, and the operation — everything the timing
//! simulator's dependency scoreboard and the functional ISS need.

use std::fmt;

use crate::acadl_core::graph::RegId;
use crate::isa::opcode::Opcode;

/// A memory address operand: known statically, or computed from a register
/// at dispatch time (`load [r9]`, Listing 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AddrRef {
    Direct(u64),
    Indirect { base: RegId, offset: i64 },
}

impl AddrRef {
    /// Registers this address reference reads (for the scoreboard).
    pub fn base_reg(&self) -> Option<RegId> {
        match self {
            AddrRef::Direct(_) => None,
            AddrRef::Indirect { base, .. } => Some(*base),
        }
    }
}

/// One ACADL instruction.  `reads`/`writes` are the paper's
/// `read_registers`/`write_registers`; `read_addrs`/`write_addrs` the
/// `read_addresses`/`write_addresses`; `imms` the `immediates`.  The
/// paper's `function`/`execute()` lives in
/// [`crate::sim::functional`] keyed by `op`.
#[derive(Debug, Clone, PartialEq)]
pub struct Instruction {
    pub op: Opcode,
    pub reads: Vec<RegId>,
    pub writes: Vec<RegId>,
    pub read_addrs: Vec<AddrRef>,
    pub write_addrs: Vec<AddrRef>,
    pub imms: Vec<i64>,
}

impl Instruction {
    pub fn new(op: Opcode) -> Self {
        Instruction {
            op,
            reads: Vec::new(),
            writes: Vec::new(),
            read_addrs: Vec::new(),
            write_addrs: Vec::new(),
            imms: Vec::new(),
        }
    }

    pub fn with_reads(mut self, reads: Vec<RegId>) -> Self {
        self.reads = reads;
        self
    }

    pub fn with_writes(mut self, writes: Vec<RegId>) -> Self {
        self.writes = writes;
        self
    }

    pub fn with_read_addrs(mut self, a: Vec<AddrRef>) -> Self {
        self.read_addrs = a;
        self
    }

    pub fn with_write_addrs(mut self, a: Vec<AddrRef>) -> Self {
        self.write_addrs = a;
        self
    }

    pub fn with_imms(mut self, imms: Vec<i64>) -> Self {
        self.imms = imms;
        self
    }

    /// All registers whose values this instruction consumes, including
    /// address base registers (scoreboard RAW edges).
    pub fn all_read_regs(&self) -> impl Iterator<Item = RegId> + '_ {
        self.reads.iter().copied().chain(
            self.read_addrs
                .iter()
                .chain(self.write_addrs.iter())
                .filter_map(|a| a.base_reg()),
        )
    }

    /// Is this a memory operation (must be handled by a MAU)?
    pub fn is_memory(&self) -> bool {
        self.op.is_memory()
    }

    /// Does this instruction write `pc` (control hazard)?
    pub fn is_control(&self) -> bool {
        self.op.is_control()
    }
}

impl fmt::Display for Instruction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.op)?;
        let mut first = true;
        for r in &self.reads {
            write!(f, "{} %{}", if first { "" } else { "," }, r.0)?;
            first = false;
        }
        for a in &self.read_addrs {
            match a {
                AddrRef::Direct(x) => write!(f, "{} [{x:#x}]", if first { "" } else { "," })?,
                AddrRef::Indirect { base, offset } => write!(
                    f,
                    "{} [%{}{:+}]",
                    if first { "" } else { "," },
                    base.0,
                    offset
                )?,
            }
            first = false;
        }
        for i in &self.imms {
            write!(f, "{} #{i}", if first { "" } else { "," })?;
            first = false;
        }
        if !self.writes.is_empty() || !self.write_addrs.is_empty() {
            write!(f, " =>")?;
            for w in &self.writes {
                write!(f, " %{}", w.0)?;
            }
            for a in &self.write_addrs {
                match a {
                    AddrRef::Direct(x) => write!(f, " [{x:#x}]")?,
                    AddrRef::Indirect { base, offset } => {
                        write!(f, " [%{}{:+}]", base.0, offset)?
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_regs_include_address_bases() {
        let i = Instruction::new(Opcode::Store)
            .with_reads(vec![RegId(1)])
            .with_write_addrs(vec![AddrRef::Indirect {
                base: RegId(11),
                offset: 0,
            }]);
        let regs: Vec<_> = i.all_read_regs().collect();
        assert_eq!(regs, vec![RegId(1), RegId(11)]);
    }

    #[test]
    fn display_is_readable() {
        let i = Instruction::new(Opcode::Mac)
            .with_reads(vec![RegId(6), RegId(7), RegId(8)])
            .with_writes(vec![RegId(8)]);
        assert_eq!(i.to_string(), "mac %6, %7, %8 => %8");
        let l = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x3000)])
            .with_writes(vec![RegId(0)]);
        assert_eq!(l.to_string(), "load [0x3000] => %0");
    }

    #[test]
    fn classification_delegates_to_opcode() {
        assert!(Instruction::new(Opcode::Load).is_memory());
        assert!(Instruction::new(Opcode::Jumpi).is_control());
        assert!(!Instruction::new(Opcode::VAdd).is_memory());
    }
}
