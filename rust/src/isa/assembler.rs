//! Two-pass assembler for the paper's listing syntax (Listings 4–5).
//!
//! ```text
//! ; tiled GeMM inner loop (Listing 5 style)
//! loop:
//!   load  [r9] => r6
//!   load  [r10] => r7
//!   mac   r6, r7, r8 => r8
//!   addi  r3, #-1 => r3
//!   beqi  r3, z0, @done => pc
//!   jumpi @loop => pc
//! done:
//!   store r8 => [r11]
//!   halt
//! ```
//!
//! Operand forms:
//! * `rX`, `pc`, `r[0].16` … — register names resolved against the AG's
//!   global register namespace,
//! * `[0x3000]` — direct memory address,
//! * `[r9]`, `[r9+8]` — register-indirect address,
//! * `#-28`, `#0x10`, bare integers — immediates,
//! * `@label` — converted to a byte offset relative to the *current*
//!   instruction (the paper's `#-28 => pc` convention, Listing 5).
//!
//! `gemm A, B, act => C` expands A/B/C into groups of [`GAMMA_TILE`]
//! consecutive registers (Listing 4: `gemm r[0].0, r[0].9, 1 => r[0].16`
//! consumes rows r[0].0–7 and r[0].9–16... r[0].9+7, producing r[0].16–23).

use std::collections::HashMap;

use thiserror::Error;

use crate::acadl_core::graph::{Ag, RegId};
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;
use crate::isa::{GAMMA_TILE, INSTR_BYTES};

#[derive(Debug, Error)]
pub enum AsmError {
    #[error("line {0}: unknown mnemonic `{1}`")]
    UnknownMnemonic(usize, String),
    #[error("line {0}: unknown register `{1}`")]
    UnknownRegister(usize, String),
    #[error("line {0}: unknown label `{1}`")]
    UnknownLabel(usize, String),
    #[error("line {0}: duplicate label `{1}`")]
    DuplicateLabel(usize, String),
    #[error("line {0}: malformed operand `{1}`")]
    BadOperand(usize, String),
    #[error("line {0}: {1}")]
    Other(usize, String),
}

#[derive(Debug, Clone)]
enum Operand {
    Reg(RegId),
    Addr(AddrRef),
    Imm(i64),
    Label(String),
}

/// Assemble `src` against the AG's register namespace, placing the program
/// at byte address `base`.
pub fn assemble(ag: &Ag, src: &str, base: u64) -> Result<Program, AsmError> {
    // Pass 1: strip comments/labels, record label addresses.
    let mut labels: HashMap<String, u64> = HashMap::new();
    let mut lines: Vec<(usize, String)> = Vec::new();
    let mut pc = base;
    for (lineno, raw) in src.lines().enumerate() {
        let mut line = raw;
        if let Some(p) = line.find(';') {
            line = &line[..p];
        }
        if let Some(p) = line.find("//") {
            line = &line[..p];
        }
        let mut line = line.trim().to_string();
        // Leading `label:` prefixes (possibly several).
        while let Some(colon) = line.find(':') {
            let (head, rest) = line.split_at(colon);
            let head = head.trim();
            if head.is_empty()
                || !head
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_')
            {
                break;
            }
            if labels.insert(head.to_string(), pc).is_some() {
                return Err(AsmError::DuplicateLabel(lineno + 1, head.to_string()));
            }
            line = rest[1..].trim().to_string();
        }
        if line.is_empty() {
            continue;
        }
        pc += INSTR_BYTES;
        lines.push((lineno + 1, line));
    }

    // Pass 2: encode.
    let mut instrs = Vec::with_capacity(lines.len());
    for (i, (lineno, line)) in lines.iter().enumerate() {
        let self_addr = base + i as u64 * INSTR_BYTES;
        instrs.push(encode_line(ag, *lineno, line, self_addr, &labels)?);
    }
    Ok(Program::new(instrs, base))
}

fn encode_line(
    ag: &Ag,
    lineno: usize,
    line: &str,
    self_addr: u64,
    labels: &HashMap<String, u64>,
) -> Result<Instruction, AsmError> {
    let (lhs, rhs) = match line.split_once("=>") {
        Some((l, r)) => (l.trim(), Some(r.trim())),
        None => (line, None),
    };
    let mut parts = lhs.splitn(2, char::is_whitespace);
    let mnemonic = parts.next().unwrap_or("");
    let op: Opcode = mnemonic
        .parse()
        .map_err(|_| AsmError::UnknownMnemonic(lineno, mnemonic.to_string()))?;
    let operands = parts
        .next()
        .map(|s| parse_operand_list(ag, lineno, s, labels))
        .transpose()?
        .unwrap_or_default();
    let dests = rhs
        .map(|s| parse_operand_list(ag, lineno, s, labels))
        .transpose()?
        .unwrap_or_default();

    let mut ins = Instruction::new(op);
    for o in operands {
        match o {
            Operand::Reg(r) => ins.reads.push(r),
            Operand::Addr(a) => ins.read_addrs.push(a),
            Operand::Imm(v) => ins.imms.push(v),
            Operand::Label(name) => {
                let target = *labels
                    .get(&name)
                    .ok_or_else(|| AsmError::UnknownLabel(lineno, name.clone()))?;
                ins.imms.push(target as i64 - self_addr as i64);
            }
        }
    }
    for d in dests {
        match d {
            Operand::Reg(r) => ins.writes.push(r),
            Operand::Addr(a) => ins.write_addrs.push(a),
            Operand::Imm(_) | Operand::Label(_) => {
                return Err(AsmError::BadOperand(
                    lineno,
                    "immediate/label cannot be a destination".into(),
                ))
            }
        }
    }

    if op == Opcode::Gemm {
        expand_gemm(ag, lineno, &mut ins)?;
    }
    // mac a, b, acc => acc — when written `mac a, b => acc`, the
    // accumulator is read implicitly; normalize so the scoreboard sees it.
    if op == Opcode::Mac && ins.reads.len() == 2 {
        if let Some(&acc) = ins.writes.first() {
            ins.reads.push(acc);
        }
    }
    Ok(ins)
}

fn parse_operand_list(
    ag: &Ag,
    lineno: usize,
    s: &str,
    _labels: &HashMap<String, u64>,
) -> Result<Vec<Operand>, AsmError> {
    let mut out = Vec::new();
    // Commas inside `[...]` don't occur in this syntax, so a flat split is
    // safe.
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(parse_operand(ag, lineno, tok)?);
    }
    Ok(out)
}

fn parse_operand(ag: &Ag, lineno: usize, tok: &str) -> Result<Operand, AsmError> {
    if let Some(rest) = tok.strip_prefix('@') {
        return Ok(Operand::Label(rest.to_string()));
    }
    if let Some(rest) = tok.strip_prefix('#') {
        return parse_int(rest)
            .map(Operand::Imm)
            .ok_or_else(|| AsmError::BadOperand(lineno, tok.to_string()));
    }
    if tok.starts_with('[') {
        let inner = tok
            .strip_prefix('[')
            .and_then(|t| t.strip_suffix(']'))
            .ok_or_else(|| AsmError::BadOperand(lineno, tok.to_string()))?
            .trim();
        if let Some(v) = parse_int(inner) {
            return Ok(Operand::Addr(AddrRef::Direct(v as u64)));
        }
        // `reg`, `reg+off`, `reg-off`
        let (reg_part, off) = match inner.rfind(['+', '-']) {
            Some(p) if p > 0 => {
                let (r, o) = inner.split_at(p);
                let off = parse_int(o)
                    .ok_or_else(|| AsmError::BadOperand(lineno, tok.to_string()))?;
                (r.trim(), off)
            }
            _ => (inner, 0),
        };
        let base = ag
            .reg_id(reg_part)
            .ok_or_else(|| AsmError::UnknownRegister(lineno, reg_part.to_string()))?;
        return Ok(Operand::Addr(AddrRef::Indirect { base, offset: off }));
    }
    if tok
        .chars()
        .next()
        .is_some_and(|c| c.is_ascii_digit() || c == '-')
    {
        return parse_int(tok)
            .map(Operand::Imm)
            .ok_or_else(|| AsmError::BadOperand(lineno, tok.to_string()));
    }
    ag.reg_id(tok)
        .map(Operand::Reg)
        .ok_or_else(|| AsmError::UnknownRegister(lineno, tok.to_string()))
}

fn parse_int(s: &str) -> Option<i64> {
    let s = s.trim();
    let (neg, body) = match s.strip_prefix('-') {
        Some(b) => (true, b),
        None => (false, s),
    };
    let v = if let Some(hex) = body.strip_prefix("0x").or_else(|| body.strip_prefix("0X")) {
        i64::from_str_radix(hex, 16).ok()?
    } else {
        body.parse::<i64>().ok()?
    };
    Some(if neg { -v } else { v })
}

/// Expand a `gemm A, B => C` into full register groups: reads A..A+7,
/// B..B+7; writes C..C+7 (Listing 4 semantics).  Register group members
/// are consecutive *names* formed by incrementing the trailing integer.
fn expand_gemm(ag: &Ag, lineno: usize, ins: &mut Instruction) -> Result<(), AsmError> {
    if ins.reads.len() != 2 || ins.writes.len() != 1 {
        return Err(AsmError::Other(
            lineno,
            format!(
                "gemm needs 2 source register groups and 1 destination (got {} / {})",
                ins.reads.len(),
                ins.writes.len()
            ),
        ));
    }
    let a0 = ins.reads[0];
    let b0 = ins.reads[1];
    let c0 = ins.writes[0];
    let mut reads = Vec::with_capacity(2 * GAMMA_TILE);
    reads.extend(reg_group(ag, lineno, a0)?);
    reads.extend(reg_group(ag, lineno, b0)?);
    ins.reads = reads;
    ins.writes = reg_group(ag, lineno, c0)?;
    Ok(())
}

/// The `n`-register group starting at `base`: names with incremented
/// trailing integers (`r[0].9` → `r[0].9 r[0].10 … r[0].16`).
fn reg_group(ag: &Ag, lineno: usize, base: RegId) -> Result<Vec<RegId>, AsmError> {
    let name = &ag.reg(base).name;
    let split = name
        .rfind(|c: char| !c.is_ascii_digit())
        .map(|p| p + 1)
        .unwrap_or(0);
    let (prefix, digits) = name.split_at(split);
    let start: u64 = digits
        .parse()
        .map_err(|_| AsmError::Other(lineno, format!("register `{name}` has no numeric suffix for group expansion")))?;
    (0..GAMMA_TILE as u64)
        .map(|i| {
            let n = format!("{prefix}{}", start + i);
            ag.reg_id(&n)
                .ok_or_else(|| AsmError::UnknownRegister(lineno, n))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::data::Data;
    use crate::acadl_core::object::build;

    fn test_ag() -> Ag {
        let mut ag = Ag::new();
        let mut regs: Vec<(String, Data)> = (0..16)
            .map(|i| (format!("r{i}"), Data::int(32, 0)))
            .collect();
        regs.push(("pc".into(), Data::int(32, 0)));
        regs.push(("z0".into(), Data::int(32, 0)));
        for i in 0..32 {
            regs.push((format!("v[0].{i}"), Data::vec(128, 8)));
        }
        ag.add(build::register_file("rf0", 32, regs)).unwrap();
        ag
    }

    #[test]
    fn listing5_style_lines() {
        let ag = test_ag();
        let p = assemble(
            &ag,
            "mov z0 => r8\n\
             load [r9] => r6\n\
             load [r10] => r7\n\
             mac r6, r7 => r8\n\
             addi r3, #-1 => r3\n\
             store r8 => [r11]\n\
             halt",
            0,
        )
        .unwrap();
        assert_eq!(p.len(), 7);
        assert_eq!(p.instrs[1].op, Opcode::Load);
        assert_eq!(p.instrs[1].read_addrs.len(), 1);
        // mac reads a, b and the accumulator.
        assert_eq!(p.instrs[3].reads.len(), 3);
        assert_eq!(p.instrs[3].writes.len(), 1);
        assert_eq!(p.instrs[4].imms, vec![-1]);
        assert!(matches!(
            p.instrs[5].write_addrs[0],
            AddrRef::Indirect { .. }
        ));
    }

    #[test]
    fn labels_resolve_to_byte_offsets() {
        let ag = test_ag();
        let p = assemble(
            &ag,
            "loop: addi r3, #-1 => r3\n\
             beqi r3, z0, @done => pc\n\
             jumpi @loop => pc\n\
             done: halt",
            0x100,
        )
        .unwrap();
        // beqi at 0x104, done at 0x10c → offset +8.
        assert_eq!(p.instrs[1].imms, vec![8]);
        // jumpi at 0x108, loop at 0x100 → offset -8.
        assert_eq!(p.instrs[2].imms, vec![-8]);
    }

    #[test]
    fn listing4_gemm_expands_groups() {
        let ag = test_ag();
        let p = assemble(
            &ag,
            "load [0x3000] => v[0].0\n\
             gemm v[0].0, v[0].8, 1 => v[0].16\n\
             store v[0].16 => [0x5000]",
            0,
        )
        .unwrap();
        let g = &p.instrs[1];
        assert_eq!(g.reads.len(), 16, "8 A rows + 8 B rows");
        assert_eq!(g.writes.len(), 8, "8 C rows");
        assert_eq!(g.imms, vec![1], "ReLU flag");
        assert_eq!(ag.reg(g.reads[8]).name, "v[0].8");
        assert_eq!(ag.reg(g.writes[7]).name, "v[0].23");
    }

    #[test]
    fn direct_and_offset_addressing() {
        let ag = test_ag();
        let p = assemble(&ag, "load [0x3030] => r1\nload [r9+8] => r2\nload [r9-4] => r3", 0)
            .unwrap();
        assert_eq!(p.instrs[0].read_addrs[0], AddrRef::Direct(0x3030));
        match p.instrs[1].read_addrs[0] {
            AddrRef::Indirect { offset, .. } => assert_eq!(offset, 8),
            other => panic!("[r9+8] must parse register-indirect, got {other:?}"),
        }
        match p.instrs[2].read_addrs[0] {
            AddrRef::Indirect { offset, .. } => assert_eq!(offset, -4),
            other => panic!("[r9-4] must parse register-indirect, got {other:?}"),
        }
    }

    #[test]
    fn malformed_operands_rejected() {
        let ag = test_ag();
        // Unterminated bracket.
        assert!(matches!(
            assemble(&ag, "load [0x3000 => r1", 0),
            Err(AsmError::BadOperand(1, _))
        ));
        // Garbage immediate.
        assert!(matches!(
            assemble(&ag, "addi r3, #xyz => r3", 0),
            Err(AsmError::BadOperand(1, _))
        ));
        // Garbage indirect offset.
        assert!(matches!(
            assemble(&ag, "load [r9+q] => r1", 0),
            Err(AsmError::BadOperand(1, _))
        ));
        // Immediates and labels cannot be destinations.
        assert!(matches!(
            assemble(&ag, "mov r1 => #5", 0),
            Err(AsmError::BadOperand(1, _))
        ));
        assert!(matches!(
            assemble(&ag, "x: mov r1 => @x", 0),
            Err(AsmError::BadOperand(1, _))
        ));
        // Unknown register inside an indirect operand.
        assert!(matches!(
            assemble(&ag, "nop\nload [rQ] => r1", 0),
            Err(AsmError::UnknownRegister(2, _))
        ));
    }

    #[test]
    fn malformed_gemm_groups_rejected() {
        let ag = test_ag();
        // Wrong operand arity.
        assert!(matches!(
            assemble(&ag, "gemm v[0].0 => v[0].16", 0),
            Err(AsmError::Other(1, _))
        ));
        // Group base without a numeric suffix cannot expand.
        assert!(matches!(
            assemble(&ag, "gemm pc, v[0].8, 1 => v[0].16", 0),
            Err(AsmError::Other(1, _))
        ));
        // Group running past the register file: v[0].25..32 with only
        // 32 vector registers (v[0].0..31) — v[0].32 does not exist.
        assert!(matches!(
            assemble(&ag, "gemm v[0].0, v[0].8, 1 => v[0].25", 0),
            Err(AsmError::UnknownRegister(1, _))
        ));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let ag = test_ag();
        assert!(matches!(
            assemble(&ag, "nop\nbogus r1 => r2", 0),
            Err(AsmError::UnknownMnemonic(2, _))
        ));
        assert!(matches!(
            assemble(&ag, "mov rX => r1", 0),
            Err(AsmError::UnknownRegister(1, _))
        ));
        assert!(matches!(
            assemble(&ag, "jumpi @nowhere => pc", 0),
            Err(AsmError::UnknownLabel(1, _))
        ));
        assert!(matches!(
            assemble(&ag, "x: nop\nx: nop", 0),
            Err(AsmError::DuplicateLabel(2, _))
        ));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let ag = test_ag();
        let p = assemble(
            &ag,
            "; full line comment\n\
             \n\
             nop ; trailing\n\
             halt // c++ style",
            0,
        )
        .unwrap();
        assert_eq!(p.len(), 2);
    }
}
