//! A `Program`: an instruction list placed in instruction memory, plus the
//! disassembler used by the CLI's `map --dump` and the experiment logs.

use std::collections::BTreeMap;

use crate::acadl_core::graph::Ag;
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::INSTR_BYTES;

/// An assembled instruction stream. Instruction `i` lives at byte address
/// `base + i * INSTR_BYTES` of the instruction memory.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub instrs: Vec<Instruction>,
    /// Base byte address in the instruction memory.
    pub base: u64,
}

impl Program {
    pub fn new(instrs: Vec<Instruction>, base: u64) -> Self {
        Program { instrs, base }
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Byte address of instruction `idx`.
    #[inline]
    pub fn addr_of(&self, idx: usize) -> u64 {
        self.base + idx as u64 * INSTR_BYTES
    }

    /// Instruction index at byte address `addr`, if in range and aligned.
    #[inline]
    pub fn index_of(&self, addr: u64) -> Option<usize> {
        if addr < self.base {
            return None;
        }
        let off = addr - self.base;
        if off % INSTR_BYTES != 0 {
            return None;
        }
        let idx = (off / INSTR_BYTES) as usize;
        (idx < self.instrs.len()).then_some(idx)
    }

    /// End byte address (exclusive).
    pub fn end_addr(&self) -> u64 {
        self.addr_of(self.instrs.len())
    }

    /// Opcode histogram (experiment logs, sanity checks).
    pub fn op_histogram(&self) -> BTreeMap<&'static str, usize> {
        let mut h = BTreeMap::new();
        for i in &self.instrs {
            *h.entry(i.op.mnemonic()).or_default() += 1;
        }
        h
    }

    /// Count of dynamic memory operands (direct only; indirect resolved at
    /// run time).
    pub fn static_mem_refs(&self) -> usize {
        self.instrs
            .iter()
            .map(|i| i.read_addrs.len() + i.write_addrs.len())
            .sum()
    }

    /// Human-readable disassembly with resolved register names.
    pub fn disassemble(&self, ag: &Ag) -> String {
        let mut out = String::new();
        for (idx, ins) in self.instrs.iter().enumerate() {
            out.push_str(&format!("{:#06x}  ", self.addr_of(idx)));
            out.push_str(&Self::format_instr(ins, ag));
            out.push('\n');
        }
        out
    }

    fn format_instr(ins: &Instruction, ag: &Ag) -> String {
        let reg = |r: &crate::acadl_core::graph::RegId| ag.reg(*r).name.clone();
        let addr = |a: &AddrRef| match a {
            AddrRef::Direct(x) => format!("[{x:#x}]"),
            AddrRef::Indirect { base, offset } if *offset == 0 => {
                format!("[{}]", reg(base))
            }
            AddrRef::Indirect { base, offset } => format!("[{}{:+}]", reg(base), offset),
        };
        let mut parts: Vec<String> = Vec::new();
        parts.extend(ins.reads.iter().map(|r| reg(r)));
        parts.extend(ins.read_addrs.iter().map(addr));
        parts.extend(ins.imms.iter().map(|i| format!("#{i}")));
        let mut dests: Vec<String> = Vec::new();
        dests.extend(ins.writes.iter().map(|r| reg(r)));
        dests.extend(ins.write_addrs.iter().map(addr));
        let lhs = if parts.is_empty() {
            ins.op.mnemonic().to_string()
        } else {
            format!("{} {}", ins.op.mnemonic(), parts.join(", "))
        };
        if dests.is_empty() {
            lhs
        } else {
            format!("{} => {}", lhs, dests.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::opcode::Opcode;

    #[test]
    fn addressing() {
        let p = Program::new(
            vec![
                Instruction::new(Opcode::Nop),
                Instruction::new(Opcode::Nop),
                Instruction::new(Opcode::Halt),
            ],
            0x100,
        );
        assert_eq!(p.addr_of(0), 0x100);
        assert_eq!(p.addr_of(2), 0x108);
        assert_eq!(p.index_of(0x104), Some(1));
        assert_eq!(p.index_of(0x106), None, "misaligned");
        assert_eq!(p.index_of(0x10c), None, "past end");
        assert_eq!(p.index_of(0xff), None, "before base");
        assert_eq!(p.end_addr(), 0x10c);
    }

    #[test]
    fn histogram() {
        let p = Program::new(
            vec![
                Instruction::new(Opcode::Mac),
                Instruction::new(Opcode::Mac),
                Instruction::new(Opcode::Halt),
            ],
            0,
        );
        let h = p.op_histogram();
        assert_eq!(h["mac"], 2);
        assert_eq!(h["halt"], 1);
    }
}
