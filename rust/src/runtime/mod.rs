//! PJRT golden-model runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client via the
//! `xla` crate.  This is the L2/L1 numerics oracle the simulated
//! accelerators are validated against (experiment E9) — Python never runs
//! here.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not a
//! serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.  Artifacts
//! are lowered with `return_tuple=True`, so results unwrap with
//! `to_tuple1()`-style tuple decomposition.
//!
//! The PJRT path needs the heavyweight native `xla` crate, so it is gated
//! behind the **`pjrt`** cargo feature.  Without the feature, [`Golden`]
//! is a stub whose loaders return [`RuntimeError::Disabled`]; all golden
//! tests skip rather than fail, and the rest of the crate builds with no
//! native dependencies.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use thiserror::Error;

#[cfg(feature = "pjrt")]
use crate::util::json::Json;
use crate::util::json::JsonError;

#[derive(Debug, Error)]
pub enum RuntimeError {
    #[error("artifacts directory `{0}` has no manifest.json — run `make artifacts`")]
    NoManifest(PathBuf),
    #[error("artifact `{0}` not in manifest")]
    UnknownArtifact(String),
    #[error("artifact `{name}` expects {expect} args, got {got}")]
    ArityMismatch {
        name: String,
        expect: usize,
        got: usize,
    },
    #[error("argument {index} of `{name}`: expected {expect} elements, got {got}")]
    ShapeMismatch {
        name: String,
        index: usize,
        expect: usize,
        got: usize,
    },
    #[error("manifest parse error: {0}")]
    Manifest(#[from] JsonError),
    #[error("io error reading {0}: {1}")]
    Io(PathBuf, std::io::Error),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("built without the `pjrt` feature — golden-model execution is disabled")]
    Disabled,
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// One tensor signature from the manifest.
#[derive(Debug, Clone)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    #[cfg(feature = "pjrt")]
    fn from_json(v: &Json) -> Result<Self, JsonError> {
        Ok(TensorSig {
            shape: v
                .field("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_, _>>()?,
            dtype: v.field("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One artifact entry of `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub file: String,
    pub args: Vec<TensorSig>,
    pub results: Vec<TensorSig>,
}

impl ArtifactSig {
    #[cfg(feature = "pjrt")]
    fn from_json(v: &Json) -> Result<Self, JsonError> {
        let sigs = |key: &str| -> Result<Vec<TensorSig>, JsonError> {
            v.field(key)?
                .as_arr()?
                .iter()
                .map(TensorSig::from_json)
                .collect()
        };
        Ok(ArtifactSig {
            file: v.field("file")?.as_str()?.to_string(),
            args: sigs("args")?,
            results: sigs("results")?,
        })
    }
}

/// The golden-model runtime: PJRT CPU client + lazily compiled
/// executables, one per artifact.
#[cfg(feature = "pjrt")]
pub struct Golden {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: HashMap<String, ArtifactSig>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Stub golden-model runtime (`pjrt` feature disabled): loaders return
/// [`RuntimeError::Disabled`], so no value of this type can ever exist —
/// the remaining methods are statically unreachable.
#[cfg(not(feature = "pjrt"))]
pub struct Golden(std::convert::Infallible);

#[cfg(not(feature = "pjrt"))]
impl Golden {
    pub fn load(_dir: impl AsRef<Path>) -> Result<Self, RuntimeError> {
        Err(RuntimeError::Disabled)
    }

    pub fn load_default() -> Result<Self, RuntimeError> {
        Err(RuntimeError::Disabled)
    }

    pub fn names(&self) -> Vec<&str> {
        match self.0 {}
    }

    pub fn signature(&self, _name: &str) -> Option<&ArtifactSig> {
        match self.0 {}
    }

    pub fn run(
        &mut self,
        _name: &str,
        _inputs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>, RuntimeError> {
        match self.0 {}
    }
}

#[cfg(feature = "pjrt")]
impl Golden {
    /// Load the manifest and create the PJRT CPU client.  Executables
    /// compile on first use and are cached for the process lifetime (one
    /// compile per model variant — the AOT contract).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        if !mpath.exists() {
            return Err(RuntimeError::NoManifest(dir));
        }
        let text =
            std::fs::read_to_string(&mpath).map_err(|e| RuntimeError::Io(mpath.clone(), e))?;
        let parsed = Json::parse(&text)?;
        let mut manifest = HashMap::new();
        for (name, entry) in parsed.as_obj()? {
            manifest.insert(name.clone(), ArtifactSig::from_json(entry)?);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Golden {
            dir,
            client,
            manifest,
            compiled: HashMap::new(),
        })
    }

    /// Default artifacts directory: `$ACADL_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self, RuntimeError> {
        let dir = std::env::var("ACADL_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.manifest.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn signature(&self, name: &str) -> Option<&ArtifactSig> {
        self.manifest.get(name)
    }

    fn compile(&mut self, name: &str) -> Result<(), RuntimeError> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let sig = self
            .manifest
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("artifact paths are utf-8"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with f32 inputs (row-major flats, one per
    /// manifest arg).  Returns the result tensors as row-major flats.
    pub fn run(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, RuntimeError> {
        self.compile(name)?;
        let sig = self.manifest.get(name).unwrap().clone();
        if inputs.len() != sig.args.len() {
            return Err(RuntimeError::ArityMismatch {
                name: name.to_string(),
                expect: sig.args.len(),
                got: inputs.len(),
            });
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, arg)) in inputs.iter().zip(&sig.args).enumerate() {
            if data.len() != arg.elements() {
                return Err(RuntimeError::ShapeMismatch {
                    name: name.to_string(),
                    index: i,
                    expect: arg.elements(),
                    got: data.len(),
                });
            }
            let dims: Vec<i64> = arg.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims)?;
            literals.push(lit);
        }
        let exe = self.compiled.get(name).unwrap();
        let mut result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // Artifacts are lowered with return_tuple=True.
        let parts = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn disabled_build_reports_disabled() {
        assert!(matches!(Golden::load_default(), Err(RuntimeError::Disabled)));
        assert!(matches!(
            Golden::load("artifacts"),
            Err(RuntimeError::Disabled)
        ));
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    //! These tests need `make artifacts` to have run; they are skipped
    //! (not failed) when the artifacts are absent so `cargo test` works in
    //! a fresh checkout.
    use super::*;

    fn golden() -> Option<Golden> {
        match Golden::load_default() {
            Ok(g) => Some(g),
            Err(RuntimeError::NoManifest(_)) => None,
            Err(e) => panic!("unexpected error: {e}"),
        }
    }

    #[test]
    fn manifest_lists_artifacts() {
        let Some(g) = golden() else { return };
        let names = g.names();
        for expect in ["gemm_8x8", "gemm_relu_8x8", "gemm_tiled_128", "mlp_forward"] {
            assert!(names.contains(&expect), "{names:?}");
        }
        let sig = g.signature("gemm_8x8").unwrap();
        assert_eq!(sig.args.len(), 2);
        assert_eq!(sig.args[0].shape, vec![8, 8]);
    }

    #[test]
    fn gemm_8x8_identity() {
        let Some(mut g) = golden() else { return };
        let mut a = vec![0.0f32; 64];
        let mut id = vec![0.0f32; 64];
        for i in 0..8 {
            for j in 0..8 {
                a[i * 8 + j] = (i * 8 + j) as f32;
            }
            id[i * 8 + i] = 1.0;
        }
        let out = g.run("gemm_8x8", &[a.clone(), id]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], a);
    }

    #[test]
    fn gemm_relu_clamps() {
        let Some(mut g) = golden() else { return };
        let a = vec![-1.0f32; 64];
        let mut id = vec![0.0f32; 64];
        for i in 0..8 {
            id[i * 8 + i] = 1.0;
        }
        let out = g.run("gemm_relu_8x8", &[a, id]).unwrap();
        assert!(out[0].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn arity_and_shape_errors() {
        let Some(mut g) = golden() else { return };
        assert!(matches!(
            g.run("gemm_8x8", &[vec![0.0; 64]]),
            Err(RuntimeError::ArityMismatch { .. })
        ));
        assert!(matches!(
            g.run("gemm_8x8", &[vec![0.0; 64], vec![0.0; 7]]),
            Err(RuntimeError::ShapeMismatch { .. })
        ));
        assert!(matches!(
            g.run("nope", &[]),
            Err(RuntimeError::UnknownArtifact(_))
        ));
    }
}
