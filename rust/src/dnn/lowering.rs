//! Lowering a DNN graph onto an accelerator: per-layer operator programs,
//! host-managed inter-layer transfers (TVM's graph-runtime role), and the
//! schedule runner that produces per-layer cycle counts (§5's "functional
//! and optional timing simulation").

use thiserror::Error;

use crate::isa::GAMMA_TILE;
use crate::mapping::gemm::{GemmLayout, GemmParams};
use crate::mapping::uma::{self, Machine, Operator, UmaError};
use crate::sim::backend::BackendKind;
use crate::sim::engine::{Engine, SimError};
use crate::sim::functional::{FuncError, FunctionalSim};

use super::graph::{DnnGraph, Layer};

/// How each layer program is simulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Program-order ISS (fast; mapping validation).
    Functional,
    /// Cycle-accurate engine (produces cycles) on the selected backend;
    /// both backends report identical cycles.
    Timed(BackendKind),
}

#[derive(Debug, Error)]
pub enum LowerError {
    #[error("layer {0}: only Dense stacks lower end-to-end (got {1})")]
    Unsupported(usize, &'static str),
    #[error(transparent)]
    Uma(#[from] UmaError),
    #[error(transparent)]
    Sim(#[from] SimError),
    #[error(transparent)]
    Func(#[from] FuncError),
}

/// One lowered layer: operator, program, layout, padded dims.
#[derive(Debug, Clone)]
pub struct LoweredLayer {
    pub name: String,
    pub op: Operator,
    pub lowered: uma::Lowered,
    /// Logical (unpadded) m, k, n.
    pub logical: (usize, usize, usize),
    /// Weights (padded, row-major k×n) and bias (padded, len n).
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
    pub relu: bool,
    pub bias_base: Option<u64>,
}

/// The whole lowered model.
#[derive(Debug, Clone)]
pub struct LoweredGraph {
    pub layers: Vec<LoweredLayer>,
    pub batch: usize,
}

/// Per-layer and total results of running a schedule.
#[derive(Debug, Clone, Default)]
pub struct ScheduleReport {
    pub per_layer: Vec<LayerReport>,
    pub total_cycles: u64,
    pub total_instructions: u64,
    /// Final activations (batch × last layer features, unpadded).
    pub output: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub cycles: u64,
    pub instructions: u64,
    pub macs: u64,
    pub ipc: f64,
}

fn pad_to(x: usize, mult: usize) -> usize {
    x.div_ceil(mult) * mult
}

/// Pad a row-major `r×c` matrix to `pr×pc` with zeros.
fn pad_matrix(data: &[f32], r: usize, c: usize, pr: usize, pc: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; pr * pc];
    for i in 0..r {
        out[i * pc..i * pc + c].copy_from_slice(&data[i * c..(i + 1) * c]);
    }
    out
}

/// Lower every Dense layer of `graph` for `machine` (batch rows).  Γ̈ pads
/// all GeMM dims to multiples of [`GAMMA_TILE`]; scalar targets use the
/// logical dims directly.  Fused bias+ReLU goes through the `Dense`
/// operator on Γ̈; scalar targets get a plain GeMM and host-applied
/// bias/activation (the data transform TVM would schedule separately).
pub fn lower_graph(
    machine: &Machine,
    graph: &DnnGraph,
    batch: usize,
) -> Result<LoweredGraph, LowerError> {
    let is_gamma = matches!(machine, Machine::Gamma(_));
    let mult = if is_gamma { GAMMA_TILE } else { 1 };
    let mut layers = Vec::new();
    for (idx, layer) in graph.layers.iter().enumerate() {
        let Layer::Dense {
            in_features,
            out_features,
            relu,
        } = layer
        else {
            return Err(LowerError::Unsupported(
                idx,
                match layer {
                    Layer::Conv2d { .. } => "Conv2d",
                    Layer::MaxPool2x2 => "MaxPool2x2",
                    Layer::Flatten => "Flatten",
                    Layer::Dense { .. } => unreachable!(),
                },
            ));
        };
        let (w, b) = graph.dense_params(idx).unwrap();
        let (m, k, n) = (batch, *in_features, *out_features);
        let (pm, pk, pn) = (pad_to(m, mult), pad_to(k, mult), pad_to(n, mult));
        let p = GemmParams::new(pm, pk, pn);
        let weights = pad_matrix(&w, k, n, pk, pn);
        let mut bias = b.clone();
        bias.resize(pn, 0.0);

        // Operand region: after the layout's C, leave room for the bias.
        let layout = GemmLayout::at(machine.data_base(), &p);
        let bias_base = layout.c_base + (pm * pn * 4) as u64;

        let op = if is_gamma {
            Operator::Dense {
                gemm: p,
                bias_base,
                relu: *relu,
            }
        } else {
            Operator::Gemm(p)
        };
        let lowered = uma::lower(machine, &op)?;
        layers.push(LoweredLayer {
            name: format!("dense{idx}_{k}x{n}"),
            op,
            lowered,
            logical: (m, k, n),
            weights,
            bias,
            relu: *relu,
            bias_base: is_gamma.then_some(bias_base),
        });
    }
    Ok(LoweredGraph { layers, batch })
}

/// Run the lowered schedule: per-layer simulation with host-managed
/// activation transfer, returning cycles and the final output.
pub fn run_schedule(
    machine: &Machine,
    lg: &LoweredGraph,
    input: &[f32],
    mode: SimMode,
    max_cycles: u64,
) -> Result<ScheduleReport, LowerError> {
    let mut report = ScheduleReport::default();
    let batch = lg.batch;
    let mut act = input.to_vec(); // batch × features, unpadded
    let mut feat = act.len() / batch;

    for ll in &lg.layers {
        let (m, k, n) = ll.logical;
        assert_eq!(feat, k, "activation width mismatch at {}", ll.name);
        let p = *ll.op.gemm_params();
        let padded_a = pad_matrix(&act, m, k, p.m, p.k);

        let (cycles, instrs, c_out) = match mode {
            SimMode::Functional => {
                let mut sim = FunctionalSim::new(machine.ag());
                ll.lowered
                    .layout
                    .load_inputs(&p, &mut sim.mem, &padded_a, &ll.weights);
                if let Some(bb) = ll.bias_base {
                    sim.mem.load_f32(bb, &ll.bias);
                }
                let st = sim.run(&ll.lowered.program, max_cycles)?;
                (0, st.instructions, ll.lowered.layout.read_c(&p, &sim.mem))
            }
            SimMode::Timed(backend) => {
                let mut e = Engine::with_backend(machine.ag(), &ll.lowered.program, backend)?;
                ll.lowered
                    .layout
                    .load_inputs(&p, &mut e.mem, &padded_a, &ll.weights);
                if let Some(bb) = ll.bias_base {
                    e.mem.load_f32(bb, &ll.bias);
                }
                let st = e.run(max_cycles)?;
                (st.cycles, st.retired, ll.lowered.layout.read_c(&p, &e.mem))
            }
        };

        // Unpad and (scalar targets) apply bias + activation on the host.
        let mut next = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut v = c_out[i * p.n + j];
                if ll.bias_base.is_none() {
                    v += ll.bias[j];
                    if ll.relu {
                        v = v.max(0.0);
                    }
                }
                next[i * n + j] = v;
            }
        }
        act = next;
        feat = n;

        report.per_layer.push(LayerReport {
            name: ll.name.clone(),
            cycles,
            instructions: instrs,
            macs: (m * k * n) as u64,
            ipc: if cycles > 0 {
                instrs as f64 / cycles as f64
            } else {
                0.0
            },
        });
        report.total_cycles += cycles;
        report.total_instructions += instrs;
    }
    report.output = act;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::gamma::GammaConfig;
    use crate::arch::oma::OmaConfig;
    use crate::mapping::uma::TargetConfig;

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }

    #[test]
    fn small_mlp_on_gamma_matches_reference() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let batch = 8;
        let lg = lower_graph(&machine, &g, batch).unwrap();
        let x = g.input_batch(batch);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 100_000_000).unwrap();
        let want = g.forward_ref(&x, batch);
        assert!(
            max_abs_diff(&rep.output, &want) < 1e-3,
            "diff={}",
            max_abs_diff(&rep.output, &want)
        );
    }

    #[test]
    fn small_mlp_on_gamma_timed_produces_cycles() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let lg = lower_graph(&machine, &g, 8).unwrap();
        let x = g.input_batch(8);
        let rep = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::CycleStepped),
            100_000_000,
        )
        .unwrap();
        assert!(rep.total_cycles > 0);
        assert_eq!(rep.per_layer.len(), 2);
        let want = g.forward_ref(&x, 8);
        assert!(max_abs_diff(&rep.output, &want) < 1e-3);

        // The event-driven backend schedules the same layers to the same
        // per-layer and total cycle counts.
        let ev = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            100_000_000,
        )
        .unwrap();
        assert_eq!(ev.total_cycles, rep.total_cycles);
        assert_eq!(ev.total_instructions, rep.total_instructions);
        assert_eq!(ev.output, rep.output);
    }

    #[test]
    fn small_mlp_on_oma_matches_reference() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let lg = lower_graph(&machine, &g, 4).unwrap();
        let x = g.input_batch(4);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
        let want = g.forward_ref(&x, 4);
        assert!(max_abs_diff(&rep.output, &want) < 1e-3);
    }

    #[test]
    fn conv_layers_report_unsupported() {
        let g = DnnGraph {
            input_features: 25,
            layers: vec![Layer::Flatten],
            name: "x".into(),
        };
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        assert!(matches!(
            lower_graph(&machine, &g, 1),
            Err(LowerError::Unsupported(0, "Flatten"))
        ));
    }
}
