//! Lowering a DNN graph onto an accelerator: per-layer operator programs,
//! host-managed inter-layer transfers (TVM's graph-runtime role), and the
//! schedule runner that produces per-layer cycle counts (§5's "functional
//! and optional timing simulation").
//!
//! Dense **and Conv2d** layers map onto the accelerator through the UMA
//! registry seam (`mapping::uma::lower`): a convolution becomes the
//! im2col patch-matrix GeMM (the `im2col_conv` composite mapper), with
//! the host performing the patch transform when loading inputs.  MaxPool
//! and Flatten are host glue steps between accelerator calls — the layout
//! transforms TVM's graph runtime would schedule on the CPU.
//!
//! The transformer layers ride the same seam: `MatMul` over a stashed
//! activation reuses the tiled-GeMM mappers (the B operand comes from a
//! stash slot instead of the weight table), while `Softmax`, `LayerNorm`,
//! `Gelu`, residual `AddResidual`, and `Transpose` lower through the
//! `scalar_rowwise` mapper onto each target's scalar unit.  `Stash` /
//! `Recall` are pure host bookkeeping — saving and restoring the running
//! activation between accelerator calls.

use std::collections::HashMap;

use thiserror::Error;

use crate::isa::GAMMA_TILE;
use crate::mapping::conv::Conv2d;
use crate::mapping::gemm::{GemmLayout, GemmParams};
use crate::mapping::uma::{self, Machine, Operator, UmaError};
use crate::sim::backend::BackendKind;
use crate::sim::engine::{Engine, SimError, SimStats};
use crate::sim::exec::MemImage;
use crate::sim::functional::{FuncError, FunctionalSim};
use crate::sim::trace::TraceData;

use super::graph::{DnnGraph, Layer};

/// How each layer program is simulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Program-order ISS (fast; mapping validation).
    Functional,
    /// Cycle-accurate engine (produces cycles) on the selected backend;
    /// both backends report identical cycles.
    Timed(BackendKind),
}

#[derive(Debug, Error)]
pub enum LowerError {
    #[error("layer {0}: cannot lower {1} here (host stages need a known spatial shape)")]
    Unsupported(usize, &'static str),
    #[error("layer {0}: {1}")]
    BadGraph(usize, String),
    #[error(transparent)]
    Uma(#[from] UmaError),
    #[error(transparent)]
    Sim(#[from] SimError),
    #[error(transparent)]
    Func(#[from] FuncError),
}

/// Where a mapped layer's B operand (the layout's second region) comes
/// from at schedule-run time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BSource {
    /// The layer's own (padded) weight matrix, fixed at lowering time.
    Weights,
    /// A stash slot's activation (activation-×-activation `MatMul`,
    /// residual `AddResidual`) — padded at run time where the target
    /// requires it.
    Stash(usize),
    /// A stash slot's activation **transposed** at run time (`MatMulT`
    /// over a row-major KV cache): the slot holds the logical `n × k`
    /// matrix and the host transposes it into the GeMM's `k × n` B
    /// operand before padding.
    StashT(usize),
    /// A single constant word (layer norm's epsilon), bit patterns fixed
    /// at lowering time.
    Eps,
    /// No second operand.
    None,
}

/// One accelerator-mapped layer: operator, program, layout, padded dims.
#[derive(Debug, Clone)]
pub struct LoweredLayer {
    pub name: String,
    pub op: Operator,
    pub lowered: uma::Lowered,
    /// Logical (unpadded) m, k, n of the operator's matrix view (for
    /// row-wise operators, `m × k` is the input and `n = k`).
    pub logical: (usize, usize, usize),
    /// GeMM B operand (padded, row-major k×n) when `b_source` is
    /// [`BSource::Weights`]; the epsilon word for [`BSource::Eps`].
    pub weights: Vec<f32>,
    /// Bias (padded, len n; empty for conv/transformer layers).
    pub bias: Vec<f32>,
    pub relu: bool,
    pub bias_base: Option<u64>,
    /// For conv layers: the convolution whose im2col patches form the A
    /// operand (per image of the batch).
    pub conv: Option<Conv2d>,
    /// Where the B region's data comes from at run time.
    pub b_source: BSource,
    /// Host-applied epilogue scale (1.0 = none) — attention's `1/√d`.
    pub scale: f32,
}

/// One step of the lowered schedule: an accelerator program or a host
/// data-transform between accelerator calls.
#[derive(Debug, Clone)]
pub enum Step {
    Mapped(LoweredLayer),
    /// 2×2 max-pool on channel-major activations of the given input shape.
    MaxPool2x2 { c: usize, h: usize, w: usize },
    /// No-op on the flat channel-major layout.
    Flatten,
    /// Save the running activation into a numbered host slot.
    Stash { slot: usize },
    /// Restore the activation saved in a numbered host slot.
    Recall { slot: usize },
    /// Append the running activation's rows to a numbered host slot
    /// (creating it when absent) — the KV-cache write.
    AppendStash { slot: usize },
    /// Causal attention mask over the `rows × cols` running activation
    /// (host step): entries `j > i + (cols − rows)` of row `i` become
    /// [`crate::dnn::graph::NEG_MASK`].
    CausalMask { rows: usize, cols: usize },
}

/// The whole lowered model.
#[derive(Debug, Clone)]
pub struct LoweredGraph {
    pub steps: Vec<Step>,
    pub batch: usize,
    /// KV-cache slots the schedule appends to, `(slot, features)` in
    /// first-append order — the slots [`lower_serving`] seeds for each
    /// decode step.
    pub append_slots: Vec<(usize, usize)>,
}

impl LoweredGraph {
    /// The accelerator-mapped layers, in schedule order.
    pub fn mapped(&self) -> impl Iterator<Item = &LoweredLayer> {
        self.steps.iter().filter_map(|s| match s {
            Step::Mapped(l) => Some(l),
            _ => None,
        })
    }
}

/// Per-layer and total results of running a schedule.
#[derive(Debug, Clone, Default)]
pub struct ScheduleReport {
    pub per_layer: Vec<LayerReport>,
    pub total_cycles: u64,
    pub total_instructions: u64,
    /// Final activations (batch × last layer features, unpadded).
    pub output: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub cycles: u64,
    pub instructions: u64,
    pub macs: u64,
    pub ipc: f64,
}

/// Aggregated per-run capture across a schedule's mapped (timed) steps:
/// the merged [`SimStats`] and one [`TraceData`] timeline with each
/// layer's engine run appended at its cumulative cycle offset — the
/// schedule is sequential on one chip, so the concatenation reads as the
/// true timeline.  Functional steps contribute nothing.
#[derive(Debug, Clone, Default)]
pub struct ScheduleCapture {
    pub stats: SimStats,
    pub trace: TraceData,
}

fn pad_to(x: usize, mult: usize) -> usize {
    x.div_ceil(mult) * mult
}

/// Pad a row-major `r×c` matrix to `pr×pc` with zeros.
fn pad_matrix(data: &[f32], r: usize, c: usize, pr: usize, pc: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; pr * pc];
    for i in 0..r {
        out[i * pc..i * pc + c].copy_from_slice(&data[i * c..(i + 1) * c]);
    }
    out
}

/// Lower every layer of `graph` for `machine` (batch rows; for the
/// transformer, batch = sequence length).  Γ̈ pads all GeMM dims to
/// multiples of [`GAMMA_TILE`]; scalar targets use the logical dims
/// directly.  Dense bias+ReLU fuses on Γ̈ (the `Dense` operator); scalar
/// targets get a plain GeMM and host-applied bias/activation.  Conv2d
/// lowers to the im2col GeMM on every target (ReLU host-applied — the
/// fused path needs a bias row); the row-wise transformer operators lower
/// to scalar-unit streaming loops; MaxPool2x2, Flatten, Stash, and Recall
/// become host steps.
pub fn lower_graph(
    machine: &Machine,
    graph: &DnnGraph,
    batch: usize,
) -> Result<LoweredGraph, LowerError> {
    lower_graph_seeded(machine, graph, batch, &HashMap::new())
}

/// [`lower_graph`] with pre-seeded stash-slot shapes: `seed` maps slot →
/// `(rows, features)` the slot already holds when the schedule starts.
/// This is how a decode step lowers against a **persistent KV cache**:
/// the same graph, at `batch = 1`, with each append slot seeded to the
/// rows accumulated by the prefill and earlier decode steps — every
/// attention GeMM then comes out rectangular (`1 × d` against the cached
/// `n × d`).
pub fn lower_graph_seeded(
    machine: &Machine,
    graph: &DnnGraph,
    batch: usize,
    seed: &HashMap<usize, (usize, usize)>,
) -> Result<LoweredGraph, LowerError> {
    let is_gamma = matches!(machine, Machine::Gamma(_));
    let mult = if is_gamma { GAMMA_TILE } else { 1 };
    let mut steps = Vec::new();
    let mut feat = graph.input_features;
    let mut rows = batch;
    let mut shape: Option<(usize, usize, usize)> = None;
    // Stash slots: (rows, features) at lowering time.
    let mut slots: HashMap<usize, (usize, usize)> = seed.clone();
    let mut append_slots: Vec<(usize, usize)> = Vec::new();
    for (idx, layer) in graph.layers.iter().enumerate() {
        match layer {
            Layer::Dense {
                in_features,
                out_features,
                relu,
            } => {
                debug_assert_eq!(feat, *in_features);
                let (w, b) = graph.dense_params(idx).unwrap();
                let (m, k, n) = (rows, *in_features, *out_features);
                let (pm, pk, pn) = (pad_to(m, mult), pad_to(k, mult), pad_to(n, mult));
                let p = GemmParams::new(pm, pk, pn);
                let weights = pad_matrix(&w, k, n, pk, pn);
                let mut bias = b.clone();
                bias.resize(pn, 0.0);

                // Operand region: after the layout's C, leave room for the
                // bias.
                let layout = GemmLayout::at(machine.data_base(), &p);
                let bias_base = layout.c_base + (pm * pn * 4) as u64;

                let op = if is_gamma {
                    Operator::Dense {
                        gemm: p,
                        bias_base,
                        relu: *relu,
                    }
                } else {
                    Operator::Gemm(p)
                };
                let lowered = uma::lower(machine, &op)?;
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("dense{idx}_{k}x{n}"),
                    op,
                    lowered,
                    logical: (m, k, n),
                    weights,
                    bias,
                    relu: *relu,
                    bias_base: is_gamma.then_some(bias_base),
                    conv: None,
                    b_source: BSource::Weights,
                    scale: 1.0,
                }));
                feat = n;
                shape = None;
            }
            Layer::Conv2d { conv, relu } => {
                debug_assert_eq!(feat, conv.in_c * conv.in_h * conv.in_w);
                debug_assert_eq!(rows, batch, "conv layers run on the full batch");
                let (oh, ow) = (conv.out_h(), conv.out_w());
                let g = conv.as_gemm(); // per-image (oh·ow) × kk × out_c
                let (m, k, n) = (batch * g.m, g.k, g.n);
                let (pm, pk, pn) = (pad_to(m, mult), pad_to(k, mult), pad_to(n, mult));
                let p = GemmParams::new(pm, pk, pn);
                let w = graph.conv_params(idx).unwrap();
                let weights = pad_matrix(&conv.reshape_weights(&w), k, n, pk, pn);
                let op = Operator::Conv2d {
                    conv: *conv,
                    gemm: p,
                };
                let lowered = uma::lower(machine, &op)?;
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("conv{idx}_{}x{}x{}", conv.out_c, oh, ow),
                    op,
                    lowered,
                    logical: (m, k, n),
                    weights,
                    bias: Vec::new(),
                    relu: *relu,
                    bias_base: None,
                    conv: Some(*conv),
                    b_source: BSource::Weights,
                    scale: 1.0,
                }));
                feat = conv.out_c * oh * ow;
                shape = Some((conv.out_c, oh, ow));
            }
            Layer::MaxPool2x2 => {
                let Some((c, h, w)) = shape else {
                    return Err(LowerError::Unsupported(idx, "MaxPool2x2"));
                };
                steps.push(Step::MaxPool2x2 { c, h, w });
                feat = c * (h / 2) * (w / 2);
                shape = Some((c, h / 2, w / 2));
            }
            Layer::Flatten => {
                steps.push(Step::Flatten);
                shape = None;
            }
            Layer::MatMul { slot, scale } => {
                let Some(&(brows, bcols)) = slots.get(slot) else {
                    return Err(LowerError::BadGraph(idx, format!("matmul reads empty slot {slot}")));
                };
                if feat != brows {
                    return Err(LowerError::BadGraph(
                        idx,
                        format!("matmul shapes: {rows}x{feat} · {brows}x{bcols}"),
                    ));
                }
                let (m, k, n) = (rows, feat, bcols);
                let (pm, pk, pn) = (pad_to(m, mult), pad_to(k, mult), pad_to(n, mult));
                let op = Operator::Gemm(GemmParams::new(pm, pk, pn));
                let lowered = uma::lower(machine, &op)?;
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("matmul{idx}_{m}x{k}x{n}"),
                    op,
                    lowered,
                    logical: (m, k, n),
                    weights: Vec::new(),
                    bias: Vec::new(),
                    relu: false,
                    bias_base: None,
                    conv: None,
                    b_source: BSource::Stash(*slot),
                    scale: *scale,
                }));
                feat = n;
                shape = None;
            }
            Layer::MatMulT { slot, scale } => {
                let Some(&(brows, bcols)) = slots.get(slot) else {
                    return Err(LowerError::BadGraph(idx, format!("matmult reads empty slot {slot}")));
                };
                if feat != bcols {
                    return Err(LowerError::BadGraph(
                        idx,
                        format!("matmult shapes: {rows}x{feat} · ({brows}x{bcols})^T"),
                    ));
                }
                let (m, k, n) = (rows, feat, brows);
                let (pm, pk, pn) = (pad_to(m, mult), pad_to(k, mult), pad_to(n, mult));
                let op = Operator::Gemm(GemmParams::new(pm, pk, pn));
                let lowered = uma::lower(machine, &op)?;
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("matmult{idx}_{m}x{k}x{n}"),
                    op,
                    lowered,
                    logical: (m, k, n),
                    weights: Vec::new(),
                    bias: Vec::new(),
                    relu: false,
                    bias_base: None,
                    conv: None,
                    b_source: BSource::StashT(*slot),
                    scale: *scale,
                }));
                feat = n;
                shape = None;
            }
            Layer::CausalMask => {
                if rows > feat {
                    return Err(LowerError::BadGraph(
                        idx,
                        format!("causal mask needs rows ≤ cols, got {rows}x{feat}"),
                    ));
                }
                steps.push(Step::CausalMask { rows, cols: feat });
            }
            Layer::AppendStash { slot } => {
                if let Some(&(srows, scols)) = slots.get(slot) {
                    if scols != feat {
                        return Err(LowerError::BadGraph(
                            idx,
                            format!("append width {feat} into slot {slot} of width {scols}"),
                        ));
                    }
                    slots.insert(*slot, (srows + rows, feat));
                } else {
                    slots.insert(*slot, (rows, feat));
                }
                if !append_slots.iter().any(|&(s, _)| s == *slot) {
                    append_slots.push((*slot, feat));
                }
                steps.push(Step::AppendStash { slot: *slot });
            }
            Layer::Softmax
            | Layer::LayerNorm { .. }
            | Layer::Gelu
            | Layer::Transpose => {
                let (op, tag) = match layer {
                    Layer::Softmax => (Operator::Softmax { rows, cols: feat }, "softmax"),
                    Layer::LayerNorm { eps } => (
                        Operator::LayerNorm {
                            rows,
                            cols: feat,
                            eps: *eps,
                        },
                        "layernorm",
                    ),
                    Layer::Gelu => (Operator::Gelu { rows, cols: feat }, "gelu"),
                    _ => (Operator::Transpose { rows, cols: feat }, "transpose"),
                };
                let lowered = uma::lower(machine, &op)?;
                let (b_source, weights) = match op {
                    Operator::LayerNorm { eps, .. } => (BSource::Eps, vec![eps]),
                    _ => (BSource::None, Vec::new()),
                };
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("{tag}{idx}_{rows}x{feat}"),
                    op,
                    lowered,
                    logical: (rows, feat, feat),
                    weights,
                    bias: Vec::new(),
                    relu: false,
                    bias_base: None,
                    conv: None,
                    b_source,
                    scale: 1.0,
                }));
                if matches!(layer, Layer::Transpose) {
                    std::mem::swap(&mut rows, &mut feat);
                }
                shape = None;
            }
            Layer::AddResidual { slot } => {
                let Some(&(brows, bcols)) = slots.get(slot) else {
                    return Err(LowerError::BadGraph(idx, format!("residual reads empty slot {slot}")));
                };
                if (rows, feat) != (brows, bcols) {
                    return Err(LowerError::BadGraph(
                        idx,
                        format!("residual shapes: {rows}x{feat} + {brows}x{bcols}"),
                    ));
                }
                let op = Operator::AddMat { rows, cols: feat };
                let lowered = uma::lower(machine, &op)?;
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("residual{idx}_{rows}x{feat}"),
                    op,
                    lowered,
                    logical: (rows, feat, feat),
                    weights: Vec::new(),
                    bias: Vec::new(),
                    relu: false,
                    bias_base: None,
                    conv: None,
                    b_source: BSource::Stash(*slot),
                    scale: 1.0,
                }));
                shape = None;
            }
            Layer::Stash { slot } => {
                steps.push(Step::Stash { slot: *slot });
                slots.insert(*slot, (rows, feat));
            }
            Layer::Recall { slot } => {
                let Some(&(srows, scols)) = slots.get(slot) else {
                    return Err(LowerError::BadGraph(idx, format!("recall of empty slot {slot}")));
                };
                steps.push(Step::Recall { slot: *slot });
                rows = srows;
                feat = scols;
                shape = None;
            }
        }
    }
    Ok(LoweredGraph {
        steps,
        batch,
        append_slots,
    })
}

/// The machine-independent operator sequence of `graph` at `batch` rows —
/// **unpadded** (target padding only raises true cycles, so bounding the
/// unpadded problem stays sound).  This is the single source the DSE
/// pre-filter sums its per-operator `Roofline::op_cycles` bound over.
pub fn roofline_ops(graph: &DnnGraph, batch: usize) -> Vec<Operator> {
    roofline_walk(graph, batch, &HashMap::new()).0
}

/// The machine-independent operator sequence of a full **serving** run:
/// one prefill pass at `seq` rows plus `decode_steps` single-row decode
/// passes, each seeded with the KV-cache rows accumulated so far —
/// mirroring [`lower_serving`]'s schedules exactly, so the analytical
/// pre-filter bounds the same work the simulator performs.
pub fn serving_roofline_ops(graph: &DnnGraph, seq: usize, decode_steps: usize) -> Vec<Operator> {
    let (mut ops, appends) = roofline_walk(graph, seq, &HashMap::new());
    for t in 0..decode_steps {
        let seed: HashMap<usize, (usize, usize)> =
            appends.iter().map(|&(slot, feat)| (slot, (seq + t, feat))).collect();
        ops.extend(roofline_walk(graph, 1, &seed).0);
    }
    ops
}

/// Shared shape walk behind [`roofline_ops`] / [`serving_roofline_ops`]:
/// returns the operator list plus the append slots `(slot, features)`
/// encountered, in first-append order.
fn roofline_walk(
    graph: &DnnGraph,
    batch: usize,
    seed: &HashMap<usize, (usize, usize)>,
) -> (Vec<Operator>, Vec<(usize, usize)>) {
    let mut ops = Vec::new();
    let mut feat = graph.input_features;
    let mut rows = batch;
    let mut slots: HashMap<usize, (usize, usize)> = seed.clone();
    let mut appends: Vec<(usize, usize)> = Vec::new();
    for layer in &graph.layers {
        match layer {
            Layer::Dense {
                in_features,
                out_features,
                ..
            } => {
                ops.push(Operator::Gemm(GemmParams::new(rows, *in_features, *out_features)));
                feat = *out_features;
            }
            Layer::Conv2d { conv, .. } => {
                let g = conv.as_gemm();
                ops.push(Operator::Gemm(GemmParams::new(batch * g.m, g.k, g.n)));
                feat = conv.out_c * conv.out_h() * conv.out_w();
            }
            Layer::MaxPool2x2 => feat /= 4,
            Layer::Flatten => {}
            Layer::MatMul { slot, .. } => {
                let (brows, bcols) = slots.get(slot).copied().unwrap_or((feat, feat));
                debug_assert_eq!(feat, brows);
                ops.push(Operator::Gemm(GemmParams::new(rows, feat, bcols)));
                feat = bcols;
            }
            Layer::MatMulT { slot, .. } => {
                let (brows, bcols) = slots.get(slot).copied().unwrap_or((feat, feat));
                debug_assert_eq!(feat, bcols);
                ops.push(Operator::Gemm(GemmParams::new(rows, feat, brows)));
                feat = brows;
            }
            Layer::CausalMask => {}
            Layer::AppendStash { slot } => {
                let srows = slots.get(slot).map_or(0, |&(r, _)| r);
                slots.insert(*slot, (srows + rows, feat));
                if !appends.iter().any(|&(s, _)| s == *slot) {
                    appends.push((*slot, feat));
                }
            }
            Layer::Softmax => ops.push(Operator::Softmax { rows, cols: feat }),
            Layer::LayerNorm { eps } => ops.push(Operator::LayerNorm {
                rows,
                cols: feat,
                eps: *eps,
            }),
            Layer::Gelu => ops.push(Operator::Gelu { rows, cols: feat }),
            Layer::AddResidual { .. } => ops.push(Operator::AddMat { rows, cols: feat }),
            Layer::Transpose => {
                ops.push(Operator::Transpose { rows, cols: feat });
                std::mem::swap(&mut rows, &mut feat);
            }
            Layer::Stash { slot } => {
                slots.insert(*slot, (rows, feat));
            }
            Layer::Recall { slot } => {
                if let Some(&(r, c)) = slots.get(slot) {
                    rows = r;
                    feat = c;
                }
            }
        }
    }
    (ops, appends)
}

/// Host-side execution state threaded between schedule steps: the
/// running activation matrix plus the numbered stash slots.  One `StepCtx`
/// is one in-flight inference — the platform simulator keeps an
/// independent context per microbatch chain, which is exactly why chains
/// can run on separate threads without sharing anything.
#[derive(Debug, Clone)]
pub struct StepCtx {
    /// Running activations (rows × features, unpadded).
    pub act: Vec<f32>,
    /// Stash slots (host-managed activation saves).
    pub stash: HashMap<usize, Vec<f32>>,
}

impl StepCtx {
    pub fn new(input: &[f32]) -> Self {
        StepCtx {
            act: input.to_vec(),
            stash: HashMap::new(),
        }
    }
}

/// Execute one schedule step against `ctx`: host glue steps transform the
/// activation in place and return `None`; mapped steps run their program
/// on `machine` and return the layer's report.  Extracted from
/// [`run_schedule`] so the platform simulator can drive arbitrary step
/// slices per chip with identical semantics.
pub fn run_step(
    machine: &Machine,
    step: &Step,
    batch: usize,
    ctx: &mut StepCtx,
    mode: SimMode,
    max_cycles: u64,
) -> Result<Option<LayerReport>, LowerError> {
    run_step_captured(machine, step, batch, ctx, mode, max_cycles, None)
}

/// [`run_step`] with an optional [`ScheduleCapture`]: timed mapped steps
/// run with a trace attached and merge their stats/trace into `cap`.
#[allow(clippy::too_many_arguments)]
pub fn run_step_captured(
    machine: &Machine,
    step: &Step,
    batch: usize,
    ctx: &mut StepCtx,
    mode: SimMode,
    max_cycles: u64,
    cap: Option<&mut ScheduleCapture>,
) -> Result<Option<LayerReport>, LowerError> {
    let ll = match step {
        Step::Mapped(ll) => ll,
        Step::MaxPool2x2 { c, h, w } => {
            ctx.act = super::graph::maxpool2x2(&ctx.act, batch, *c, *h, *w);
            return Ok(None);
        }
        Step::Flatten => return Ok(None),
        Step::Stash { slot } => {
            ctx.stash.insert(*slot, ctx.act.clone());
            return Ok(None);
        }
        Step::Recall { slot } => {
            ctx.act = ctx
                .stash
                .get(slot)
                .expect("lower_graph validated stash slots")
                .clone();
            return Ok(None);
        }
        Step::AppendStash { slot } => {
            let StepCtx { act, stash } = ctx;
            match stash.get_mut(slot) {
                Some(v) => v.extend_from_slice(act),
                None => {
                    stash.insert(*slot, act.clone());
                }
            }
            return Ok(None);
        }
        Step::CausalMask { rows, cols } => {
            debug_assert_eq!(ctx.act.len(), rows * cols, "causal mask shape");
            let off = cols - rows;
            for i in 0..*rows {
                for v in &mut ctx.act[i * cols + i + off + 1..(i + 1) * cols] {
                    *v = crate::dnn::graph::NEG_MASK;
                }
            }
            return Ok(None);
        }
    };
    {
        let act = &mut ctx.act;
        let stash = &mut ctx.stash;
        let (m, k, n) = ll.logical;
        let gemm = ll.op.gemm_params().copied();

        // Assemble the A operand: GeMM-backed layers pad the activations
        // (conv layers im2col each image's patches first); row-wise
        // layers stream the logical matrix directly.
        let a_data: Vec<f32> = match (&gemm, &ll.conv) {
            (Some(p), None) => {
                assert_eq!(act.len(), m * k, "activation width mismatch at {}", ll.name);
                pad_matrix(&act, m, k, p.m, p.k)
            }
            (Some(p), Some(conv)) => {
                let in_feat = conv.in_c * conv.in_h * conv.in_w;
                assert_eq!(act.len(), batch * in_feat, "conv input mismatch at {}", ll.name);
                let rows_per_img = conv.out_h() * conv.out_w();
                let mut a = Vec::with_capacity(m * k);
                for bi in 0..batch {
                    a.extend(conv.im2col(&act[bi * in_feat..(bi + 1) * in_feat]));
                }
                debug_assert_eq!(a.len(), batch * rows_per_img * k);
                pad_matrix(&a, m, k, p.m, p.k)
            }
            (None, _) => {
                assert_eq!(act.len(), m * k, "activation width mismatch at {}", ll.name);
                act.clone()
            }
        };
        // Assemble the B operand per source.
        let b_data: Vec<f32> = match ll.b_source {
            BSource::Weights | BSource::Eps => ll.weights.clone(),
            BSource::Stash(slot) => {
                let s = stash.get(&slot).expect("lower_graph validated stash slots");
                match &gemm {
                    Some(p) => {
                        // MatMul: the stashed operand is the logical k×n
                        // B matrix, padded to the target's tile.
                        assert_eq!(s.len(), k * n, "stashed operand shape at {}", ll.name);
                        pad_matrix(s, k, n, p.k, p.n)
                    }
                    None => {
                        // AddMat: the second addend is rows×cols like the
                        // input — the operator's own B-region size.
                        assert_eq!(s.len(), ll.op.b_words(), "stashed operand shape at {}", ll.name);
                        s.clone()
                    }
                }
            }
            BSource::StashT(slot) => {
                // MatMulT: the slot holds the logical n×k cache (one row
                // per cached token); transpose on the host into the
                // GeMM's k×n B operand, then pad.
                let s = stash.get(&slot).expect("lower_graph validated stash slots");
                assert_eq!(s.len(), n * k, "cached operand shape at {}", ll.name);
                let p = gemm.as_ref().expect("StashT backs a GeMM");
                let t = crate::mapping::rowwise::transpose_ref(n, k, s);
                pad_matrix(&t, k, n, p.k, p.n)
            }
            BSource::None => Vec::new(),
        };
        let lay = &ll.lowered.layout;
        let load = |mem: &mut MemImage| {
            mem.load_f32(lay.a_base, &a_data);
            if !b_data.is_empty() {
                mem.load_f32(lay.b_base, &b_data);
            }
            if let Some(bb) = ll.bias_base {
                mem.load_f32(bb, &ll.bias);
            }
        };

        let (cycles, instrs, c_out) = match mode {
            SimMode::Functional => {
                let mut sim = FunctionalSim::new(machine.ag());
                load(&mut sim.mem);
                let st = sim.run(&ll.lowered.program, max_cycles)?;
                (0, st.instructions, sim.mem.dump_f32(lay.c_base, ll.op.c_words()))
            }
            SimMode::Timed(backend) => {
                let mut e = Engine::with_backend(machine.ag(), &ll.lowered.program, backend)?;
                if cap.is_some() {
                    e.attach_trace();
                }
                load(&mut e.mem);
                let st = e.run(max_cycles)?;
                if let Some(cap) = cap {
                    // Offset by the cycles accumulated so far: the layers
                    // run back-to-back on this one chip.
                    let offset = cap.stats.cycles;
                    if let Some(tr) = e.take_trace() {
                        cap.trace.append_offset(tr, offset);
                    }
                    cap.stats.merge(&st);
                }
                (st.cycles, st.retired, e.mem.dump_f32(lay.c_base, ll.op.c_words()))
            }
        };

        // Unpad, then post-process on the host.
        *act = match (&gemm, &ll.conv) {
            (None, _) => c_out, // row-wise: logical output, no padding
            (Some(p), None) => {
                // GeMM/Dense: unpad; apply bias + activation where not
                // fused on-device; apply the epilogue scale.
                let mut next = vec![0.0f32; m * n];
                for i in 0..m {
                    for j in 0..n {
                        let mut v = c_out[i * p.n + j];
                        if ll.bias_base.is_none() && !ll.bias.is_empty() {
                            v += ll.bias[j];
                            if ll.relu {
                                v = v.max(0.0);
                            }
                        }
                        if ll.scale != 1.0 {
                            v *= ll.scale;
                        }
                        next[i * n + j] = v;
                    }
                }
                next
            }
            (Some(p), Some(conv)) => {
                // Conv: GeMM rows are (image, pixel) × out_c; transpose to
                // channel-major (C,H,W) per image, ReLU on the host.
                let rows_per_img = conv.out_h() * conv.out_w();
                let out_feat = conv.out_c * rows_per_img;
                let mut next = vec![0.0f32; batch * out_feat];
                for bi in 0..batch {
                    for px in 0..rows_per_img {
                        for o in 0..conv.out_c {
                            let mut v = c_out[(bi * rows_per_img + px) * p.n + o];
                            if ll.relu {
                                v = v.max(0.0);
                            }
                            next[bi * out_feat + o * rows_per_img + px] = v;
                        }
                    }
                }
                next
            }
        };

        Ok(Some(LayerReport {
            name: ll.name.clone(),
            cycles,
            instructions: instrs,
            macs: gemm.map_or(0, |_| (m * k * n) as u64),
            ipc: if cycles > 0 {
                instrs as f64 / cycles as f64
            } else {
                0.0
            },
        }))
    }
}

/// Run the lowered schedule: per-layer simulation with host-managed
/// activation transfer, returning cycles and the final output.
pub fn run_schedule(
    machine: &Machine,
    lg: &LoweredGraph,
    input: &[f32],
    mode: SimMode,
    max_cycles: u64,
) -> Result<ScheduleReport, LowerError> {
    run_schedule_captured(machine, lg, input, mode, max_cycles, None)
}

/// [`run_schedule`] with an optional [`ScheduleCapture`] accumulating
/// merged stats and a concatenated trace over the mapped steps.
pub fn run_schedule_captured(
    machine: &Machine,
    lg: &LoweredGraph,
    input: &[f32],
    mode: SimMode,
    max_cycles: u64,
    cap: Option<&mut ScheduleCapture>,
) -> Result<ScheduleReport, LowerError> {
    let mut ctx = StepCtx::new(input);
    run_steps_captured(machine, lg, &mut ctx, mode, max_cycles, cap)
}

/// Run a schedule against a **caller-owned** [`StepCtx`]: the context's
/// stash slots persist across invocations, which is exactly how the KV
/// cache survives from the prefill schedule into each decode step.
fn run_steps_captured(
    machine: &Machine,
    lg: &LoweredGraph,
    ctx: &mut StepCtx,
    mode: SimMode,
    max_cycles: u64,
    mut cap: Option<&mut ScheduleCapture>,
) -> Result<ScheduleReport, LowerError> {
    let mut report = ScheduleReport::default();
    for step in &lg.steps {
        if let Some(lr) = run_step_captured(
            machine,
            step,
            lg.batch,
            ctx,
            mode,
            max_cycles,
            cap.as_deref_mut(),
        )? {
            report.total_cycles += lr.cycles;
            report.total_instructions += lr.instructions;
            report.per_layer.push(lr);
        }
    }
    report.output = ctx.act.clone();
    Ok(report)
}

// ---------------------------------------------------------------------
// Serving: prefill + KV-cached decode
// ---------------------------------------------------------------------

/// A phase-structured serving schedule: one **prefill** lowering at
/// `seq` rows plus one **decode** lowering per generated token, each
/// decode step lowered at a single row with the KV-cache slots seeded to
/// the rows accumulated so far (`seq + t`).  All schedules share the
/// graph, so they have identical step counts — one [`Step`] per graph
/// layer — and any platform partition of the prefill applies verbatim to
/// every decode step.
#[derive(Debug, Clone)]
pub struct ServingSchedule {
    pub prefill: LoweredGraph,
    /// One single-row schedule per decode step, in generation order.
    pub decode: Vec<LoweredGraph>,
    /// Prompt length the prefill was lowered at.
    pub seq: usize,
}

/// Lower `graph` for the full serving loop on `machine`: prefill at
/// `seq` rows, then `decode_steps` single-row schedules whose KV-cache
/// slots are seeded to `(seq + t, features)`.
pub fn lower_serving(
    machine: &Machine,
    graph: &DnnGraph,
    seq: usize,
    decode_steps: usize,
) -> Result<ServingSchedule, LowerError> {
    let prefill = lower_graph(machine, graph, seq)?;
    let mut decode = Vec::with_capacity(decode_steps);
    for t in 0..decode_steps {
        let seed: HashMap<usize, (usize, usize)> = prefill
            .append_slots
            .iter()
            .map(|&(slot, feat)| (slot, (seq + t, feat)))
            .collect();
        decode.push(lower_graph_seeded(machine, graph, 1, &seed)?);
    }
    Ok(ServingSchedule {
        prefill,
        decode,
        seq,
    })
}

/// Split a teacher-forced `(seq + steps) × feat` input into the prompt
/// (`seq` rows) and one single-row input per decode step — decode step
/// `t` is fed row `seq + t`, so the assembled serving output is directly
/// comparable to a from-scratch forward pass over the full input.
pub fn split_serving_input(full: &[f32], feat: usize, seq: usize) -> (Vec<f32>, Vec<Vec<f32>>) {
    assert!(feat > 0 && full.len() % feat == 0 && full.len() / feat >= seq);
    let steps = full.len() / feat - seq;
    let prompt = full[..seq * feat].to_vec();
    let decode = (0..steps)
        .map(|t| full[(seq + t) * feat..(seq + t + 1) * feat].to_vec())
        .collect();
    (prompt, decode)
}

/// Results of a full serving run: the prefill report plus one report per
/// decoded token.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub prefill: ScheduleReport,
    pub decode: Vec<ScheduleReport>,
    pub total_cycles: u64,
    pub total_instructions: u64,
}

impl ServingReport {
    /// Cycles spent in the decode phase (all tokens).
    pub fn decode_cycles(&self) -> u64 {
        self.decode.iter().map(|d| d.total_cycles).sum()
    }

    /// The serving deployment's objective: decode cycles per generated
    /// token.  `None` when no tokens were decoded.
    pub fn cycles_per_token(&self) -> Option<f64> {
        (!self.decode.is_empty())
            .then(|| self.decode_cycles() as f64 / self.decode.len() as f64)
    }

    /// Prefill output rows followed by one row per decoded token —
    /// row-compatible with `forward_ref` over the extended sequence.
    pub fn assembled_output(&self) -> Vec<f32> {
        let mut out = self.prefill.output.clone();
        for d in &self.decode {
            out.extend_from_slice(&d.output);
        }
        out
    }
}

/// Run a serving schedule: the prefill populates the KV cache, then each
/// decode step runs its single-row schedule against the **same**
/// persistent [`StepCtx`] — appending one row per step to every cache
/// slot — with `decode_inputs[t]` as the teacher-forced token input.
pub fn run_serving(
    machine: &Machine,
    sched: &ServingSchedule,
    prompt: &[f32],
    decode_inputs: &[Vec<f32>],
    mode: SimMode,
    max_cycles: u64,
) -> Result<ServingReport, LowerError> {
    run_serving_captured(machine, sched, prompt, decode_inputs, mode, max_cycles, None)
}

/// [`run_serving`] with an optional [`ScheduleCapture`]: one concatenated
/// trace/stats timeline across the prefill and every decode step.
#[allow(clippy::too_many_arguments)]
pub fn run_serving_captured(
    machine: &Machine,
    sched: &ServingSchedule,
    prompt: &[f32],
    decode_inputs: &[Vec<f32>],
    mode: SimMode,
    max_cycles: u64,
    mut cap: Option<&mut ScheduleCapture>,
) -> Result<ServingReport, LowerError> {
    assert_eq!(
        decode_inputs.len(),
        sched.decode.len(),
        "one teacher-forced input per decode step"
    );
    let mut ctx = StepCtx::new(prompt);
    let prefill =
        run_steps_captured(machine, &sched.prefill, &mut ctx, mode, max_cycles, cap.as_deref_mut())?;
    let mut decode = Vec::with_capacity(sched.decode.len());
    for (lg, input) in sched.decode.iter().zip(decode_inputs) {
        ctx.act = input.clone();
        decode.push(run_steps_captured(machine, lg, &mut ctx, mode, max_cycles, cap.as_deref_mut())?);
    }
    let total_cycles = prefill.total_cycles + decode.iter().map(|d| d.total_cycles).sum::<u64>();
    let total_instructions =
        prefill.total_instructions + decode.iter().map(|d| d.total_instructions).sum::<u64>();
    Ok(ServingReport {
        prefill,
        decode,
        total_cycles,
        total_instructions,
    })
}

// ---------------------------------------------------------------------
// Layer-wise platform partitioning
// ---------------------------------------------------------------------

/// One platform pipeline stage: a contiguous slice of the schedule (layer
/// indices — `lower_graph` emits exactly one [`Step`] per graph layer, so
/// the range indexes both `graph.layers` and `LoweredGraph::steps`), its
/// analytical compute cost, its boundary activation shapes, and the
/// weight words its chip streams from the shared DRAM.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSchedule {
    pub steps: std::ops::Range<usize>,
    /// Analytical cost (MACs for GeMM-backed layers, streamed words for
    /// row-wise layers) — the min-max partitioning objective.
    pub cost: u64,
    /// Activation shape entering the stage (rows × features).
    pub in_rows: usize,
    pub in_feat: usize,
    /// Activation shape leaving the stage.
    pub out_rows: usize,
    pub out_feat: usize,
    /// Dense/conv parameter words resident on this stage's chip.
    pub weight_words: usize,
}

impl StageSchedule {
    /// Words entering the stage (the inter-chip transfer payload).
    pub fn in_words(&self) -> usize {
        self.in_rows * self.in_feat
    }

    /// Words leaving the stage.
    pub fn out_words(&self) -> usize {
        self.out_rows * self.out_feat
    }
}

/// A DNN graph sharded across platform chips: one [`StageSchedule`] per
/// chip actually used (never more stages than splittable atoms exist).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlatformPlan {
    pub stages: Vec<StageSchedule>,
}

impl PlatformPlan {
    /// Largest stage cost — the pipeline's steady-state bottleneck.
    pub fn bottleneck_cost(&self) -> u64 {
        self.stages.iter().map(|s| s.cost).max().unwrap_or(0)
    }
}

/// Per-layer analytical trace: (cost, weight_words, rows/feat *before*
/// the layer), mirroring `lower_graph`'s shape tracking.  `boundaries`
/// additionally gets the shape after the final layer.
struct LayerTrace {
    cost: Vec<u64>,
    weight_words: Vec<usize>,
    /// (rows, feat) before layer i, plus one trailing entry after the
    /// last layer — length `layers + 1`.
    boundaries: Vec<(usize, usize)>,
}

fn trace_layers(graph: &DnnGraph, batch: usize) -> LayerTrace {
    let mut cost = Vec::with_capacity(graph.layers.len());
    let mut weight_words = Vec::with_capacity(graph.layers.len());
    let mut boundaries = Vec::with_capacity(graph.layers.len() + 1);
    let mut feat = graph.input_features;
    let mut rows = batch;
    let mut slots: HashMap<usize, (usize, usize)> = HashMap::new();
    for layer in &graph.layers {
        boundaries.push((rows, feat));
        let (c, w) = match layer {
            Layer::Dense {
                in_features,
                out_features,
                ..
            } => {
                let c = (rows * in_features * out_features) as u64;
                let w = in_features * out_features + out_features;
                feat = *out_features;
                (c, w)
            }
            Layer::Conv2d { conv, .. } => {
                let g = conv.as_gemm();
                let c = (batch * g.m * g.k * g.n) as u64;
                let w = conv.out_c * conv.in_c * conv.k_h * conv.k_w;
                feat = conv.out_c * conv.out_h() * conv.out_w();
                (c, w)
            }
            Layer::MaxPool2x2 => {
                let c = (rows * feat) as u64;
                feat /= 4;
                (c, 0)
            }
            Layer::Flatten => (0, 0),
            Layer::MatMul { slot, .. } => {
                let (_, bcols) = slots.get(slot).copied().unwrap_or((feat, feat));
                let c = (rows * feat * bcols) as u64;
                feat = bcols;
                (c, 0)
            }
            Layer::MatMulT { slot, .. } => {
                let (brows, _) = slots.get(slot).copied().unwrap_or((feat, feat));
                let c = (rows * feat * brows) as u64;
                feat = brows;
                (c, 0)
            }
            Layer::CausalMask => (0, 0),
            Layer::AppendStash { slot } => {
                let srows = slots.get(slot).map_or(0, |&(r, _)| r);
                slots.insert(*slot, (srows + rows, feat));
                (0, 0)
            }
            Layer::Softmax | Layer::LayerNorm { .. } | Layer::Gelu => ((rows * feat) as u64, 0),
            Layer::AddResidual { .. } => ((rows * feat) as u64, 0),
            Layer::Transpose => {
                let c = (rows * feat) as u64;
                std::mem::swap(&mut rows, &mut feat);
                (c, 0)
            }
            Layer::Stash { slot } => {
                slots.insert(*slot, (rows, feat));
                (0, 0)
            }
            Layer::Recall { slot } => {
                if let Some(&(r, c)) = slots.get(slot) {
                    rows = r;
                    feat = c;
                }
                (0, 0)
            }
        };
        cost.push(c);
        weight_words.push(w);
    }
    boundaries.push((rows, feat));
    LayerTrace {
        cost,
        weight_words,
        boundaries,
    }
}

/// Boundary positions (between layer `i-1` and `i`) that no stash-slot
/// live range crosses — a split is legal only where every slot a later
/// layer reads is also written later, so each chip's stash starts empty.
fn legal_boundaries(graph: &DnnGraph) -> Vec<bool> {
    let n = graph.layers.len();
    // For each read, the position of the most recent preceding write.
    let mut last_write: HashMap<usize, usize> = HashMap::new();
    // crossing[i] = some live range spans the boundary before layer i.
    let mut crossing = vec![false; n + 1];
    for (idx, layer) in graph.layers.iter().enumerate() {
        let read = match layer {
            Layer::MatMul { slot, .. }
            | Layer::MatMulT { slot, .. }
            | Layer::AddResidual { slot }
            | Layer::Recall { slot }
            // An append extends what an earlier write left in the slot,
            // so it reads the slot too — KV-cache live ranges pin each
            // attention block onto one chip.
            | Layer::AppendStash { slot } => Some(*slot),
            _ => None,
        };
        if let Some(slot) = read {
            if let Some(&w) = last_write.get(&slot) {
                // The value written at w is read at idx: boundaries
                // strictly inside (w, idx] are illegal.
                for b in crossing.iter_mut().take(idx + 1).skip(w + 1) {
                    *b = true;
                }
            }
        }
        if let Layer::Stash { slot } | Layer::AppendStash { slot } = layer {
            last_write.insert(*slot, idx);
        }
    }
    crossing.iter().map(|&c| !c).collect()
}

/// Shard `graph` across up to `chips` pipeline stages: contiguous layer
/// ranges cut only at stash-legal boundaries, balanced by exact min-max
/// dynamic programming over the analytical per-layer costs.  Uses fewer
/// stages than `chips` when the graph has fewer splittable atoms.
pub fn partition_graph(
    graph: &DnnGraph,
    batch: usize,
    chips: usize,
) -> Result<PlatformPlan, LowerError> {
    if graph.layers.is_empty() {
        return Err(LowerError::BadGraph(0, "cannot partition an empty graph".into()));
    }
    let trace = trace_layers(graph, batch);
    let legal = legal_boundaries(graph);

    // Atoms: maximal unsplittable layer runs between legal boundaries.
    let mut atom_start = vec![0usize];
    for (i, &ok) in legal.iter().enumerate().take(graph.layers.len()).skip(1) {
        if ok {
            atom_start.push(i);
        }
    }
    atom_start.push(graph.layers.len());
    let atoms = atom_start.len() - 1;
    let atom_cost: Vec<u64> = (0..atoms)
        .map(|a| trace.cost[atom_start[a]..atom_start[a + 1]].iter().sum())
        .collect();

    let stages = chips.max(1).min(atoms);
    // dp[s][i] = minimal max-stage-cost partitioning atoms[..i] into s
    // stages; cut[s][i] = the split position achieving it.
    let prefix: Vec<u64> = std::iter::once(0)
        .chain(atom_cost.iter().scan(0u64, |acc, &c| {
            *acc += c;
            Some(*acc)
        }))
        .collect();
    let range_cost = |a: usize, b: usize| prefix[b] - prefix[a];
    let mut dp = vec![vec![u64::MAX; atoms + 1]; stages + 1];
    let mut cut = vec![vec![0usize; atoms + 1]; stages + 1];
    dp[0][0] = 0;
    for s in 1..=stages {
        for i in s..=atoms {
            for j in (s - 1)..i {
                if dp[s - 1][j] == u64::MAX {
                    continue;
                }
                let cand = dp[s - 1][j].max(range_cost(j, i));
                if cand < dp[s][i] {
                    dp[s][i] = cand;
                    cut[s][i] = j;
                }
            }
        }
    }

    // Walk the cuts back into atom ranges, then into layer ranges.
    let mut splits = vec![atoms];
    let mut i = atoms;
    for s in (1..=stages).rev() {
        i = cut[s][i];
        splits.push(i);
    }
    splits.reverse(); // [0, …, atoms]

    let mut plan = Vec::with_capacity(stages);
    for w in splits.windows(2) {
        let (a0, a1) = (w[0], w[1]);
        let (l0, l1) = (atom_start[a0], atom_start[a1]);
        plan.push(StageSchedule {
            steps: l0..l1,
            cost: range_cost(a0, a1),
            in_rows: trace.boundaries[l0].0,
            in_feat: trace.boundaries[l0].1,
            out_rows: trace.boundaries[l1].0,
            out_feat: trace.boundaries[l1].1,
            weight_words: trace.weight_words[l0..l1].iter().sum(),
        });
    }
    Ok(PlatformPlan { stages: plan })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::gamma::GammaConfig;
    use crate::arch::oma::OmaConfig;
    use crate::arch::systolic::SystolicConfig;
    use crate::dnn::graph::DnnGraph;
    use crate::mapping::uma::TargetConfig;

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }

    #[test]
    fn small_mlp_on_gamma_matches_reference() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let batch = 8;
        let lg = lower_graph(&machine, &g, batch).unwrap();
        let x = g.input_batch(batch);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 100_000_000).unwrap();
        let want = g.forward_ref(&x, batch);
        assert!(
            max_abs_diff(&rep.output, &want) < 1e-3,
            "diff={}",
            max_abs_diff(&rep.output, &want)
        );
    }

    #[test]
    fn small_mlp_on_gamma_timed_produces_cycles() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let lg = lower_graph(&machine, &g, 8).unwrap();
        let x = g.input_batch(8);
        let rep = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::CycleStepped),
            100_000_000,
        )
        .unwrap();
        assert!(rep.total_cycles > 0);
        assert_eq!(rep.per_layer.len(), 2);
        let want = g.forward_ref(&x, 8);
        assert!(max_abs_diff(&rep.output, &want) < 1e-3);

        // The event-driven backend schedules the same layers to the same
        // per-layer and total cycle counts.
        let ev = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            100_000_000,
        )
        .unwrap();
        assert_eq!(ev.total_cycles, rep.total_cycles);
        assert_eq!(ev.total_instructions, rep.total_instructions);
        assert_eq!(ev.output, rep.output);
    }

    #[test]
    fn small_mlp_on_oma_matches_reference_exactly() {
        // The OMA's GeMM accumulates k-sequentially from zero with the
        // bias applied by the host epilogue — the exact order of
        // `forward_ref`, so the match is bit-exact, not a tolerance.
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let lg = lower_graph(&machine, &g, 4).unwrap();
        let x = g.input_batch(4);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
        assert_eq!(rep.output, g.forward_ref(&x, 4));
    }

    #[test]
    fn small_cnn_lowers_end_to_end_on_all_targets() {
        let g = DnnGraph::cnn_small();
        let batch = 2;
        let x = g.input_batch(batch);
        let want = g.forward_ref(&x, batch);
        for t in [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(4, 4)),
            TargetConfig::Gamma(GammaConfig::new(2)),
        ] {
            let machine = t.build().unwrap();
            let lg = lower_graph(&machine, &g, batch).unwrap();
            // conv + pool + flatten + dense = 4 schedule steps, 2 mapped.
            assert_eq!(lg.steps.len(), 4);
            assert_eq!(lg.mapped().count(), 2);
            let rep =
                run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
            let diff = max_abs_diff(&rep.output, &want);
            assert!(diff < 1e-2, "{}: diff={diff}", machine.name());
        }
    }

    #[test]
    fn small_cnn_timed_on_gamma_counts_conv_cycles() {
        let g = DnnGraph::cnn_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let lg = lower_graph(&machine, &g, 1).unwrap();
        let x = g.input_batch(1);
        let rep = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            500_000_000,
        )
        .unwrap();
        assert_eq!(rep.per_layer.len(), 2);
        assert!(rep.per_layer[0].name.starts_with("conv"), "{:?}", rep.per_layer[0]);
        assert!(rep.per_layer[0].cycles > 0);
        let want = g.forward_ref(&x, 1);
        assert!(max_abs_diff(&rep.output, &want) < 1e-2);
    }

    #[test]
    fn pool_without_shape_reports_unsupported() {
        let g = DnnGraph {
            input_features: 25,
            layers: vec![crate::dnn::graph::Layer::MaxPool2x2],
            name: "x".into(),
        };
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        assert!(matches!(
            lower_graph(&machine, &g, 1),
            Err(LowerError::Unsupported(0, "MaxPool2x2"))
        ));
    }

    // ----------------------------------------------------- transformer

    #[test]
    fn tiny_transformer_exact_on_oma_and_systolic() {
        // Full-stack bit-exactness: every layer of the transformer —
        // GeMMs included — reproduces `forward_ref` exactly on the
        // sequentially-accumulating targets.
        let g = DnnGraph::tiny_transformer();
        let seq = 8;
        let x = g.input_batch(seq);
        let want = g.forward_ref(&x, seq);
        for t in [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(2, 2)),
        ] {
            let machine = t.build().unwrap();
            let lg = lower_graph(&machine, &g, seq).unwrap();
            assert_eq!(lg.mapped().count(), 18, "8 dense + 2 matmul + 8 row-wise");
            let rep =
                run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
            assert_eq!(rep.output, want, "bit-exact on {}", machine.name());
        }
    }

    #[test]
    fn tiny_transformer_on_gamma_matches_reference() {
        // Γ̈'s 8×8-tiled GeMM accumulates per tile, so the match is a
        // tight tolerance rather than bit equality; the row-wise
        // operators still run on the scalar epilogue in reference order.
        let g = DnnGraph::tiny_transformer();
        let seq = 8;
        let machine = TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap();
        let lg = lower_graph(&machine, &g, seq).unwrap();
        let x = g.input_batch(seq);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
        let want = g.forward_ref(&x, seq);
        let diff = max_abs_diff(&rep.output, &want);
        assert!(diff < 1e-3, "diff={diff}");
    }

    #[test]
    fn tiny_transformer_timed_backends_agree_on_cycles() {
        let g = DnnGraph::tiny_transformer();
        let seq = 8;
        let machine = TargetConfig::Systolic(SystolicConfig::new(2, 2)).build().unwrap();
        let lg = lower_graph(&machine, &g, seq).unwrap();
        let x = g.input_batch(seq);
        let cs = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::CycleStepped),
            500_000_000,
        )
        .unwrap();
        let ev = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            500_000_000,
        )
        .unwrap();
        assert!(cs.total_cycles > 0);
        assert_eq!(cs.total_cycles, ev.total_cycles);
        assert_eq!(cs.total_instructions, ev.total_instructions);
        assert_eq!(cs.output, ev.output);
        assert_eq!(cs.output, g.forward_ref(&x, seq), "timed state ≡ reference");
        // Every mapped layer produced a report row with cycles.
        assert_eq!(cs.per_layer.len(), 18);
        assert!(cs.per_layer.iter().all(|l| l.cycles > 0));
    }

    #[test]
    fn tiny_transformer_odd_sequence_length_pads_on_gamma() {
        // Sequence length 6 is not a multiple of Γ̈'s tile: every GeMM —
        // including the activation-×-activation attention matmuls over
        // stashed operands — pads transparently.
        let g = DnnGraph::tiny_transformer();
        let seq = 6;
        let machine = TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap();
        let lg = lower_graph(&machine, &g, seq).unwrap();
        let x = g.input_batch(seq);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
        let want = g.forward_ref(&x, seq);
        let diff = max_abs_diff(&rep.output, &want);
        assert!(diff < 1e-3, "diff={diff}");
        assert_eq!(rep.output.len(), seq * 8);
    }

    #[test]
    fn bad_slot_usage_reports_graph_errors() {
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let bad = |layers: Vec<Layer>| DnnGraph {
            input_features: 4,
            layers,
            name: "bad".into(),
        };
        assert!(matches!(
            lower_graph(&machine, &bad(vec![Layer::MatMul { slot: 0, scale: 1.0 }]), 2),
            Err(LowerError::BadGraph(0, _))
        ));
        assert!(matches!(
            lower_graph(&machine, &bad(vec![Layer::Recall { slot: 3 }]), 2),
            Err(LowerError::BadGraph(0, _))
        ));
        // Residual against a mismatched shape.
        let g = bad(vec![
            Layer::Stash { slot: 0 },
            Layer::Dense {
                in_features: 4,
                out_features: 6,
                relu: false,
            },
            Layer::AddResidual { slot: 0 },
        ]);
        assert!(matches!(
            lower_graph(&machine, &g, 2),
            Err(LowerError::BadGraph(2, _))
        ));
    }

    #[test]
    fn roofline_ops_mirror_the_schedule() {
        let g = DnnGraph::tiny_transformer();
        let ops = roofline_ops(&g, 8);
        // 18 mapped operators (stash/recall are host bookkeeping).
        assert_eq!(ops.len(), 18);
        let gemms = ops.iter().filter(|o| o.gemm_params().is_some()).count();
        assert_eq!(gemms, 10, "8 dense + 2 attention matmuls");
        assert!(ops
            .iter()
            .any(|o| matches!(o, Operator::Softmax { rows: 8, cols: 8 })));
        assert!(ops
            .iter()
            .any(|o| matches!(o, Operator::Transpose { rows: 8, cols: 16 })));
        // MLP graphs reduce to their dense GeMMs.
        let mlp = roofline_ops(&DnnGraph::mlp_small(), 4);
        assert_eq!(mlp.len(), 2);
        assert!(mlp.iter().all(|o| o.gemm_params().is_some()));
    }

    // ----------------------------------------------------- partitioning

    #[test]
    fn run_step_slices_reproduce_run_schedule() {
        // Driving the schedule step-by-step through StepCtx is the same
        // computation run_schedule performs — the platform simulator
        // depends on this equivalence.
        let g = DnnGraph::tiny_transformer();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let lg = lower_graph(&machine, &g, 8).unwrap();
        let x = g.input_batch(8);
        let whole = run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
        let mut ctx = StepCtx::new(&x);
        for step in &lg.steps {
            run_step(&machine, step, 8, &mut ctx, SimMode::Functional, 500_000_000).unwrap();
        }
        assert_eq!(ctx.act, whole.output);
    }

    #[test]
    fn transformer_partitions_at_stash_safe_boundaries() {
        let g = DnnGraph::tiny_transformer();
        // Live slot ranges pin layers 2–15 and 17–21 together: the legal
        // split points are exactly {1, 2, 16, 17, 22, 23}.
        let legal = legal_boundaries(&g);
        let cuts: Vec<usize> = (1..g.layers.len()).filter(|&i| legal[i]).collect();
        assert_eq!(cuts, vec![1, 2, 16, 17, 22, 23]);

        let plan = partition_graph(&g, 8, 4).unwrap();
        assert_eq!(plan.stages.len(), 4);
        // Stages tile the schedule contiguously.
        assert_eq!(plan.stages[0].steps.start, 0);
        assert_eq!(plan.stages.last().unwrap().steps.end, g.layers.len());
        for w in plan.stages.windows(2) {
            assert_eq!(w[0].steps.end, w[1].steps.start);
            // Boundary shapes chain: producer out == consumer in.
            assert_eq!((w[0].out_rows, w[0].out_feat), (w[1].in_rows, w[1].in_feat));
        }
        // The attention block (layers 2..=15) is unsplittable, so it
        // dominates whichever stage holds it.
        let attn = plan
            .stages
            .iter()
            .find(|s| s.steps.contains(&11))
            .expect("some stage holds the attention matmul");
        assert!(attn.steps.start <= 2 && attn.steps.end >= 16);
        assert_eq!(plan.bottleneck_cost(), attn.cost);
        // Weight words are conserved across the shard.
        let total: usize = plan.stages.iter().map(|s| s.weight_words).sum();
        assert_eq!(total, g.parameter_count());
    }

    #[test]
    fn partitioning_clamps_to_available_atoms() {
        let g = DnnGraph::mlp_small();
        // 2 dense layers, no stash slots: at most 2 stages.
        let plan = partition_graph(&g, 4, 8).unwrap();
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.stages[0].in_words(), 4 * 16);
        assert_eq!(plan.stages[0].out_words(), 4 * 24);
        assert_eq!(plan.stages[1].out_words(), 4 * 8);
        // chips = 1 keeps the whole model on one stage.
        let one = partition_graph(&g, 4, 1).unwrap();
        assert_eq!(one.stages.len(), 1);
        assert_eq!(one.stages[0].cost, plan.stages[0].cost + plan.stages[1].cost);
        // An empty graph cannot be partitioned.
        let empty = DnnGraph {
            input_features: 4,
            layers: vec![],
            name: "empty".into(),
        };
        assert!(partition_graph(&empty, 4, 2).is_err());
    }

    // ----------------------------------------------------- serving

    #[test]
    fn parameterized_transformer_prefill_matches_reference() {
        let g = DnnGraph::transformer(2, 2);
        let seq = 4;
        let x = g.input_batch(seq);
        let want = g.forward_ref(&x, seq);
        for t in [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(2, 2)),
        ] {
            let machine = t.build().unwrap();
            let lg = lower_graph(&machine, &g, seq).unwrap();
            assert_eq!(lg.steps.len(), g.layers.len(), "one step per graph layer");
            let rep =
                run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
            assert_eq!(rep.output, want, "bit-exact on {}", machine.name());
        }
        let gamma = TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap();
        let lg = lower_graph(&gamma, &g, seq).unwrap();
        let rep = run_schedule(&gamma, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
        assert!(max_abs_diff(&rep.output, &want) < 1e-3);
    }

    #[test]
    fn kv_cached_decode_equals_extended_prefill() {
        // The serving oracle at the lowering layer: prefill(seq) plus t
        // incremental single-row decode steps produce, bit-for-bit, the
        // rows a from-scratch prefill of the extended sequence produces.
        let g = DnnGraph::transformer(1, 2);
        let (seq, steps) = (3, 2);
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let sched = lower_serving(&machine, &g, seq, steps).unwrap();
        assert_eq!(sched.decode.len(), steps);
        for lg in &sched.decode {
            assert_eq!(lg.batch, 1);
            assert_eq!(lg.steps.len(), sched.prefill.steps.len());
        }
        // 2 heads × (K, V) slots for the single layer.
        assert_eq!(sched.prefill.append_slots.len(), 4);
        let full = g.input_batch(seq + steps);
        let (prompt, dec) = split_serving_input(&full, g.input_features, seq);
        let rep = run_serving(&machine, &sched, &prompt, &dec, SimMode::Functional, 500_000_000)
            .unwrap();
        let lg_full = lower_graph(&machine, &g, seq + steps).unwrap();
        let scratch =
            run_schedule(&machine, &lg_full, &full, SimMode::Functional, 500_000_000).unwrap();
        assert_eq!(rep.assembled_output(), scratch.output, "decode ≡ extended prefill");
        assert_eq!(rep.assembled_output(), g.forward_ref(&full, seq + steps));
    }

    #[test]
    fn serving_timed_backends_agree_and_split_phase_cycles() {
        let g = DnnGraph::transformer(2, 2);
        let machine = TargetConfig::Systolic(SystolicConfig::new(2, 2)).build().unwrap();
        let sched = lower_serving(&machine, &g, 4, 2).unwrap();
        let full = g.input_batch(6);
        let (prompt, dec) = split_serving_input(&full, g.input_features, 4);
        let run = |backend| {
            run_serving(
                &machine,
                &sched,
                &prompt,
                &dec,
                SimMode::Timed(backend),
                500_000_000,
            )
            .unwrap()
        };
        let cs = run(BackendKind::CycleStepped);
        let ev = run(BackendKind::EventDriven);
        assert!(cs.prefill.total_cycles > 0 && cs.decode_cycles() > 0);
        assert_eq!(cs.total_cycles, ev.total_cycles);
        assert_eq!(cs.assembled_output(), ev.assembled_output());
        assert_eq!(cs.total_cycles, cs.prefill.total_cycles + cs.decode_cycles());
        assert!(cs.cycles_per_token().unwrap() > 0.0);
        // Decoding one token is cheaper than prefilling four.
        assert!(cs.decode[0].total_cycles < cs.prefill.total_cycles);
    }

    #[test]
    fn serving_roofline_mirrors_the_schedules() {
        let g = DnnGraph::transformer(2, 2);
        let prefill_ops = roofline_ops(&g, 4);
        let serving = serving_roofline_ops(&g, 4, 3);
        // Each of the 3 decode walks emits the same operator count as the
        // prefill walk (ops don't appear or vanish with the row count).
        assert_eq!(serving.len(), prefill_ops.len() * 4);
        // Decode attention GeMMs are rectangular: step 0 scores one query
        // row against the 5-deep cache.
        assert!(serving
            .iter()
            .any(|o| matches!(o, Operator::Gemm(p) if p.m == 1 && p.n == 5)));
        assert!(serving
            .iter()
            .any(|o| matches!(o, Operator::Gemm(p) if p.m == 1 && p.k == 5)));
    }

    #[test]
    fn causal_mask_and_matmult_report_graph_errors() {
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let g = DnnGraph {
            input_features: 2,
            layers: vec![Layer::CausalMask],
            name: "cm".into(),
        };
        assert!(matches!(
            lower_graph(&machine, &g, 3),
            Err(LowerError::BadGraph(0, _))
        ));
        let g2 = DnnGraph {
            input_features: 2,
            layers: vec![Layer::MatMulT { slot: 0, scale: 1.0 }],
            name: "mt".into(),
        };
        assert!(matches!(
            lower_graph(&machine, &g2, 2),
            Err(LowerError::BadGraph(0, _))
        ));
    }

    #[test]
    fn partition_balances_costs_min_max() {
        // Four dense layers with one heavy outlier: the DP must isolate
        // the outlier rather than greedily halving the layer count.
        let dense = |i: usize, o: usize| Layer::Dense {
            in_features: i,
            out_features: o,
            relu: false,
        };
        let g = DnnGraph {
            input_features: 8,
            layers: vec![dense(8, 8), dense(8, 64), dense(64, 8), dense(8, 8)],
            name: "lop".into(),
        };
        let plan = partition_graph(&g, 2, 2).unwrap();
        assert_eq!(plan.stages.len(), 2);
        // costs: 128, 1024, 1024, 128 → best max is 1152, never 2048.
        assert_eq!(plan.bottleneck_cost(), 1152);
    }
}
