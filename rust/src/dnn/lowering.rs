//! Lowering a DNN graph onto an accelerator: per-layer operator programs,
//! host-managed inter-layer transfers (TVM's graph-runtime role), and the
//! schedule runner that produces per-layer cycle counts (§5's "functional
//! and optional timing simulation").
//!
//! Dense **and Conv2d** layers map onto the accelerator through the UMA
//! registry seam (`mapping::uma::lower`): a convolution becomes the
//! im2col patch-matrix GeMM (the `im2col_conv` composite mapper), with
//! the host performing the patch transform when loading inputs.  MaxPool
//! and Flatten are host glue steps between accelerator calls — the layout
//! transforms TVM's graph runtime would schedule on the CPU.

use thiserror::Error;

use crate::isa::GAMMA_TILE;
use crate::mapping::conv::Conv2d;
use crate::mapping::gemm::{GemmLayout, GemmParams};
use crate::mapping::uma::{self, Machine, Operator, UmaError};
use crate::sim::backend::BackendKind;
use crate::sim::engine::{Engine, SimError};
use crate::sim::functional::{FuncError, FunctionalSim};

use super::graph::{DnnGraph, Layer};

/// How each layer program is simulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// Program-order ISS (fast; mapping validation).
    Functional,
    /// Cycle-accurate engine (produces cycles) on the selected backend;
    /// both backends report identical cycles.
    Timed(BackendKind),
}

#[derive(Debug, Error)]
pub enum LowerError {
    #[error("layer {0}: cannot lower {1} here (host stages need a known spatial shape)")]
    Unsupported(usize, &'static str),
    #[error(transparent)]
    Uma(#[from] UmaError),
    #[error(transparent)]
    Sim(#[from] SimError),
    #[error(transparent)]
    Func(#[from] FuncError),
}

/// One accelerator-mapped layer: operator, program, layout, padded dims.
#[derive(Debug, Clone)]
pub struct LoweredLayer {
    pub name: String,
    pub op: Operator,
    pub lowered: uma::Lowered,
    /// Logical (unpadded) m, k, n of the GeMM view.
    pub logical: (usize, usize, usize),
    /// GeMM B operand (padded, row-major k×n).
    pub weights: Vec<f32>,
    /// Bias (padded, len n; empty for conv layers).
    pub bias: Vec<f32>,
    pub relu: bool,
    pub bias_base: Option<u64>,
    /// For conv layers: the convolution whose im2col patches form the A
    /// operand (per image of the batch).
    pub conv: Option<Conv2d>,
}

/// One step of the lowered schedule: an accelerator program or a host
/// data-transform between accelerator calls.
#[derive(Debug, Clone)]
pub enum Step {
    Mapped(LoweredLayer),
    /// 2×2 max-pool on channel-major activations of the given input shape.
    MaxPool2x2 { c: usize, h: usize, w: usize },
    /// No-op on the flat channel-major layout.
    Flatten,
}

/// The whole lowered model.
#[derive(Debug, Clone)]
pub struct LoweredGraph {
    pub steps: Vec<Step>,
    pub batch: usize,
}

impl LoweredGraph {
    /// The accelerator-mapped layers, in schedule order.
    pub fn mapped(&self) -> impl Iterator<Item = &LoweredLayer> {
        self.steps.iter().filter_map(|s| match s {
            Step::Mapped(l) => Some(l),
            _ => None,
        })
    }
}

/// Per-layer and total results of running a schedule.
#[derive(Debug, Clone, Default)]
pub struct ScheduleReport {
    pub per_layer: Vec<LayerReport>,
    pub total_cycles: u64,
    pub total_instructions: u64,
    /// Final activations (batch × last layer features, unpadded).
    pub output: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub cycles: u64,
    pub instructions: u64,
    pub macs: u64,
    pub ipc: f64,
}

fn pad_to(x: usize, mult: usize) -> usize {
    x.div_ceil(mult) * mult
}

/// Pad a row-major `r×c` matrix to `pr×pc` with zeros.
fn pad_matrix(data: &[f32], r: usize, c: usize, pr: usize, pc: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; pr * pc];
    for i in 0..r {
        out[i * pc..i * pc + c].copy_from_slice(&data[i * c..(i + 1) * c]);
    }
    out
}

/// Lower every layer of `graph` for `machine` (batch rows).  Γ̈ pads all
/// GeMM dims to multiples of [`GAMMA_TILE`]; scalar targets use the
/// logical dims directly.  Dense bias+ReLU fuses on Γ̈ (the `Dense`
/// operator); scalar targets get a plain GeMM and host-applied
/// bias/activation.  Conv2d lowers to the im2col GeMM on every target
/// (ReLU host-applied — the fused path needs a bias row); MaxPool2x2 and
/// Flatten become host steps.
pub fn lower_graph(
    machine: &Machine,
    graph: &DnnGraph,
    batch: usize,
) -> Result<LoweredGraph, LowerError> {
    let is_gamma = matches!(machine, Machine::Gamma(_));
    let mult = if is_gamma { GAMMA_TILE } else { 1 };
    let mut steps = Vec::new();
    let mut feat = graph.input_features;
    let mut shape: Option<(usize, usize, usize)> = None;
    for (idx, layer) in graph.layers.iter().enumerate() {
        match layer {
            Layer::Dense {
                in_features,
                out_features,
                relu,
            } => {
                debug_assert_eq!(feat, *in_features);
                let (w, b) = graph.dense_params(idx).unwrap();
                let (m, k, n) = (batch, *in_features, *out_features);
                let (pm, pk, pn) = (pad_to(m, mult), pad_to(k, mult), pad_to(n, mult));
                let p = GemmParams::new(pm, pk, pn);
                let weights = pad_matrix(&w, k, n, pk, pn);
                let mut bias = b.clone();
                bias.resize(pn, 0.0);

                // Operand region: after the layout's C, leave room for the
                // bias.
                let layout = GemmLayout::at(machine.data_base(), &p);
                let bias_base = layout.c_base + (pm * pn * 4) as u64;

                let op = if is_gamma {
                    Operator::Dense {
                        gemm: p,
                        bias_base,
                        relu: *relu,
                    }
                } else {
                    Operator::Gemm(p)
                };
                let lowered = uma::lower(machine, &op)?;
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("dense{idx}_{k}x{n}"),
                    op,
                    lowered,
                    logical: (m, k, n),
                    weights,
                    bias,
                    relu: *relu,
                    bias_base: is_gamma.then_some(bias_base),
                    conv: None,
                }));
                feat = n;
                shape = None;
            }
            Layer::Conv2d { conv, relu } => {
                debug_assert_eq!(feat, conv.in_c * conv.in_h * conv.in_w);
                let (oh, ow) = (conv.out_h(), conv.out_w());
                let g = conv.as_gemm(); // per-image (oh·ow) × kk × out_c
                let (m, k, n) = (batch * g.m, g.k, g.n);
                let (pm, pk, pn) = (pad_to(m, mult), pad_to(k, mult), pad_to(n, mult));
                let p = GemmParams::new(pm, pk, pn);
                let w = graph.conv_params(idx).unwrap();
                let weights = pad_matrix(&conv.reshape_weights(&w), k, n, pk, pn);
                let op = Operator::Conv2d {
                    conv: *conv,
                    gemm: p,
                };
                let lowered = uma::lower(machine, &op)?;
                steps.push(Step::Mapped(LoweredLayer {
                    name: format!("conv{idx}_{}x{}x{}", conv.out_c, oh, ow),
                    op,
                    lowered,
                    logical: (m, k, n),
                    weights,
                    bias: Vec::new(),
                    relu: *relu,
                    bias_base: None,
                    conv: Some(*conv),
                }));
                feat = conv.out_c * oh * ow;
                shape = Some((conv.out_c, oh, ow));
            }
            Layer::MaxPool2x2 => {
                let Some((c, h, w)) = shape else {
                    return Err(LowerError::Unsupported(idx, "MaxPool2x2"));
                };
                steps.push(Step::MaxPool2x2 { c, h, w });
                feat = c * (h / 2) * (w / 2);
                shape = Some((c, h / 2, w / 2));
            }
            Layer::Flatten => {
                steps.push(Step::Flatten);
                shape = None;
            }
        }
    }
    Ok(LoweredGraph { steps, batch })
}

/// Run the lowered schedule: per-layer simulation with host-managed
/// activation transfer, returning cycles and the final output.
pub fn run_schedule(
    machine: &Machine,
    lg: &LoweredGraph,
    input: &[f32],
    mode: SimMode,
    max_cycles: u64,
) -> Result<ScheduleReport, LowerError> {
    let mut report = ScheduleReport::default();
    let batch = lg.batch;
    let mut act = input.to_vec(); // batch × features, unpadded

    for step in &lg.steps {
        let ll = match step {
            Step::Mapped(ll) => ll,
            Step::MaxPool2x2 { c, h, w } => {
                act = super::graph::maxpool2x2(&act, batch, *c, *h, *w);
                continue;
            }
            Step::Flatten => continue,
        };
        let (m, k, n) = ll.logical;
        let p = *ll.op.gemm_params();

        // Assemble the (m×k) A operand: dense layers use the activations
        // directly; conv layers im2col each image's patches.
        let a = match &ll.conv {
            None => {
                assert_eq!(act.len(), m * k, "activation width mismatch at {}", ll.name);
                act.clone()
            }
            Some(conv) => {
                let in_feat = conv.in_c * conv.in_h * conv.in_w;
                assert_eq!(act.len(), batch * in_feat, "conv input mismatch at {}", ll.name);
                let rows_per_img = conv.out_h() * conv.out_w();
                let mut a = Vec::with_capacity(m * k);
                for bi in 0..batch {
                    a.extend(conv.im2col(&act[bi * in_feat..(bi + 1) * in_feat]));
                }
                debug_assert_eq!(a.len(), batch * rows_per_img * k);
                a
            }
        };
        let padded_a = pad_matrix(&a, m, k, p.m, p.k);

        let (cycles, instrs, c_out) = match mode {
            SimMode::Functional => {
                let mut sim = FunctionalSim::new(machine.ag());
                ll.lowered
                    .layout
                    .load_inputs(&p, &mut sim.mem, &padded_a, &ll.weights);
                if let Some(bb) = ll.bias_base {
                    sim.mem.load_f32(bb, &ll.bias);
                }
                let st = sim.run(&ll.lowered.program, max_cycles)?;
                (0, st.instructions, ll.lowered.layout.read_c(&p, &sim.mem))
            }
            SimMode::Timed(backend) => {
                let mut e = Engine::with_backend(machine.ag(), &ll.lowered.program, backend)?;
                ll.lowered
                    .layout
                    .load_inputs(&p, &mut e.mem, &padded_a, &ll.weights);
                if let Some(bb) = ll.bias_base {
                    e.mem.load_f32(bb, &ll.bias);
                }
                let st = e.run(max_cycles)?;
                (st.cycles, st.retired, ll.lowered.layout.read_c(&p, &e.mem))
            }
        };

        // Unpad, then post-process on the host.
        act = match &ll.conv {
            None => {
                // Dense: apply bias + activation where not fused on-device.
                let mut next = vec![0.0f32; m * n];
                for i in 0..m {
                    for j in 0..n {
                        let mut v = c_out[i * p.n + j];
                        if ll.bias_base.is_none() {
                            v += ll.bias[j];
                            if ll.relu {
                                v = v.max(0.0);
                            }
                        }
                        next[i * n + j] = v;
                    }
                }
                next
            }
            Some(conv) => {
                // Conv: GeMM rows are (image, pixel) × out_c; transpose to
                // channel-major (C,H,W) per image, ReLU on the host.
                let rows_per_img = conv.out_h() * conv.out_w();
                let out_feat = conv.out_c * rows_per_img;
                let mut next = vec![0.0f32; batch * out_feat];
                for bi in 0..batch {
                    for px in 0..rows_per_img {
                        for o in 0..conv.out_c {
                            let mut v = c_out[(bi * rows_per_img + px) * p.n + o];
                            if ll.relu {
                                v = v.max(0.0);
                            }
                            next[bi * out_feat + o * rows_per_img + px] = v;
                        }
                    }
                }
                next
            }
        };

        report.per_layer.push(LayerReport {
            name: ll.name.clone(),
            cycles,
            instructions: instrs,
            macs: (m * k * n) as u64,
            ipc: if cycles > 0 {
                instrs as f64 / cycles as f64
            } else {
                0.0
            },
        });
        report.total_cycles += cycles;
        report.total_instructions += instrs;
    }
    report.output = act;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::gamma::GammaConfig;
    use crate::arch::oma::OmaConfig;
    use crate::arch::systolic::SystolicConfig;
    use crate::dnn::graph::DnnGraph;
    use crate::mapping::uma::TargetConfig;

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }

    #[test]
    fn small_mlp_on_gamma_matches_reference() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let batch = 8;
        let lg = lower_graph(&machine, &g, batch).unwrap();
        let x = g.input_batch(batch);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 100_000_000).unwrap();
        let want = g.forward_ref(&x, batch);
        assert!(
            max_abs_diff(&rep.output, &want) < 1e-3,
            "diff={}",
            max_abs_diff(&rep.output, &want)
        );
    }

    #[test]
    fn small_mlp_on_gamma_timed_produces_cycles() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let lg = lower_graph(&machine, &g, 8).unwrap();
        let x = g.input_batch(8);
        let rep = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::CycleStepped),
            100_000_000,
        )
        .unwrap();
        assert!(rep.total_cycles > 0);
        assert_eq!(rep.per_layer.len(), 2);
        let want = g.forward_ref(&x, 8);
        assert!(max_abs_diff(&rep.output, &want) < 1e-3);

        // The event-driven backend schedules the same layers to the same
        // per-layer and total cycle counts.
        let ev = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            100_000_000,
        )
        .unwrap();
        assert_eq!(ev.total_cycles, rep.total_cycles);
        assert_eq!(ev.total_instructions, rep.total_instructions);
        assert_eq!(ev.output, rep.output);
    }

    #[test]
    fn small_mlp_on_oma_matches_reference() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let lg = lower_graph(&machine, &g, 4).unwrap();
        let x = g.input_batch(4);
        let rep = run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
        let want = g.forward_ref(&x, 4);
        assert!(max_abs_diff(&rep.output, &want) < 1e-3);
    }

    #[test]
    fn small_cnn_lowers_end_to_end_on_all_targets() {
        let g = DnnGraph::cnn_small();
        let batch = 2;
        let x = g.input_batch(batch);
        let want = g.forward_ref(&x, batch);
        for t in [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(4, 4)),
            TargetConfig::Gamma(GammaConfig::new(2)),
        ] {
            let machine = t.build().unwrap();
            let lg = lower_graph(&machine, &g, batch).unwrap();
            // conv + pool + flatten + dense = 4 schedule steps, 2 mapped.
            assert_eq!(lg.steps.len(), 4);
            assert_eq!(lg.mapped().count(), 2);
            let rep =
                run_schedule(&machine, &lg, &x, SimMode::Functional, 500_000_000).unwrap();
            let diff = max_abs_diff(&rep.output, &want);
            assert!(diff < 1e-2, "{}: diff={diff}", machine.name());
        }
    }

    #[test]
    fn small_cnn_timed_on_gamma_counts_conv_cycles() {
        let g = DnnGraph::cnn_small();
        let machine = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
        let lg = lower_graph(&machine, &g, 1).unwrap();
        let x = g.input_batch(1);
        let rep = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            500_000_000,
        )
        .unwrap();
        assert_eq!(rep.per_layer.len(), 2);
        assert!(rep.per_layer[0].name.starts_with("conv"), "{:?}", rep.per_layer[0]);
        assert!(rep.per_layer[0].cycles > 0);
        let want = g.forward_ref(&x, 1);
        assert!(max_abs_diff(&rep.output, &want) < 1e-2);
    }

    #[test]
    fn pool_without_shape_reports_unsupported() {
        let g = DnnGraph {
            input_features: 25,
            layers: vec![crate::dnn::graph::Layer::MaxPool2x2],
            name: "x".into(),
        };
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        assert!(matches!(
            lower_graph(&machine, &g, 1),
            Err(LowerError::Unsupported(0, "MaxPool2x2"))
        ));
    }
}
