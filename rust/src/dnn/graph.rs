//! A minimal sequential DNN graph IR: the shapes the mapping layer needs,
//! with deterministic parameter initialization for experiments (the PyTorch
//! / TVM ingestion role of §5, per DESIGN.md's substitution table).

use crate::mapping::conv::Conv2d;

/// One layer of a sequential model.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// Fully connected: `out = act(x · W + b)`, W is in×out.
    Dense {
        in_features: usize,
        out_features: usize,
        relu: bool,
    },
    /// 2-D convolution followed by optional ReLU (lowered via im2col).
    Conv2d { conv: Conv2d, relu: bool },
    /// 2×2 max-pool halving H and W (lowered on the host between
    /// accelerator calls, like TVM's layout-transform glue).
    MaxPool2x2,
    Flatten,
}

/// A sequential DNN: input shape + layers + deterministic parameters.
#[derive(Debug, Clone)]
pub struct DnnGraph {
    /// Flattened input feature count (batch comes from the workload).
    pub input_features: usize,
    pub layers: Vec<Layer>,
    pub name: String,
}

impl DnnGraph {
    /// The E9 end-to-end model: 784-256-128-10 MLP (hidden ReLU).
    pub fn mlp_784_256_128_10() -> Self {
        DnnGraph {
            input_features: 784,
            layers: vec![
                Layer::Dense {
                    in_features: 784,
                    out_features: 256,
                    relu: true,
                },
                Layer::Dense {
                    in_features: 256,
                    out_features: 128,
                    relu: true,
                },
                Layer::Dense {
                    in_features: 128,
                    out_features: 10,
                    relu: false,
                },
            ],
            name: "mlp_784_256_128_10".into(),
        }
    }

    /// A small MLP for fast tests.
    pub fn mlp_small() -> Self {
        DnnGraph {
            input_features: 16,
            layers: vec![
                Layer::Dense {
                    in_features: 16,
                    out_features: 24,
                    relu: true,
                },
                Layer::Dense {
                    in_features: 24,
                    out_features: 8,
                    relu: false,
                },
            ],
            name: "mlp_small".into(),
        }
    }

    /// Deterministic pseudo-random parameters for layer `idx`:
    /// (weights row-major in×out, bias len out).  Same scheme as the
    /// Python golden models' seeded init (xorshift over layer index).
    pub fn dense_params(&self, idx: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let Layer::Dense {
            in_features,
            out_features,
            ..
        } = self.layers.get(idx)?
        else {
            return None;
        };
        let mut s = (idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (((s >> 16) % 2001) as f32 - 1000.0) / 10_000.0 // ±0.1
        };
        let w: Vec<f32> = (0..in_features * out_features).map(|_| next()).collect();
        let b: Vec<f32> = (0..*out_features).map(|_| next()).collect();
        Some((w, b))
    }

    /// Deterministic input batch (batch × input_features).
    pub fn input_batch(&self, batch: usize) -> Vec<f32> {
        let mut s = 0xDEAD_BEEF_u64;
        (0..batch * self.input_features)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (((s >> 8) % 201) as f32 - 100.0) / 100.0
            })
            .collect()
    }

    /// Host-side reference forward pass (row-major, batch × features).
    pub fn forward_ref(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let mut h = x.to_vec();
        let mut feat = self.input_features;
        for (idx, layer) in self.layers.iter().enumerate() {
            match layer {
                Layer::Dense {
                    in_features,
                    out_features,
                    relu,
                } => {
                    assert_eq!(feat, *in_features);
                    let (w, b) = self.dense_params(idx).unwrap();
                    let mut out = vec![0.0f32; batch * out_features];
                    for bi in 0..batch {
                        for o in 0..*out_features {
                            let mut acc = b[o];
                            for i in 0..*in_features {
                                acc += h[bi * in_features + i] * w[i * out_features + o];
                            }
                            out[bi * out_features + o] = if *relu { acc.max(0.0) } else { acc };
                        }
                    }
                    h = out;
                    feat = *out_features;
                }
                _ => unimplemented!("reference path covers dense stacks"),
            }
        }
        h
    }

    pub fn parameter_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Dense {
                    in_features,
                    out_features,
                    ..
                } => in_features * out_features + out_features,
                Layer::Conv2d { conv, .. } => {
                    conv.out_c * conv.in_c * conv.k_h * conv.k_w
                }
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_shapes_and_params() {
        let g = DnnGraph::mlp_784_256_128_10();
        assert_eq!(g.parameter_count(), 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
        let (w, b) = g.dense_params(0).unwrap();
        assert_eq!(w.len(), 784 * 256);
        assert_eq!(b.len(), 256);
        // Deterministic.
        assert_eq!(g.dense_params(0).unwrap().0[..8], w[..8]);
    }

    #[test]
    fn forward_ref_runs() {
        let g = DnnGraph::mlp_small();
        let x = g.input_batch(4);
        let y = g.forward_ref(&x, 4);
        assert_eq!(y.len(), 4 * 8);
        assert!(y.iter().any(|&v| v != 0.0));
    }
}
