//! A minimal DNN graph IR: the shapes the mapping layer needs, with
//! deterministic parameter initialization for experiments (the PyTorch
//! / TVM ingestion role of §5, per DESIGN.md's substitution table).
//!
//! The IR is a layer *sequence* over one running activation matrix
//! (`rows × features`, rows = batch / sequence tokens), extended with
//! numbered **stash slots** (`Stash` / `Recall`) so non-linear dataflow —
//! attention's Q/K/V fan-out, residual skip connections,
//! activation-×-activation `MatMul` — still expresses as a flat schedule.
//! That is exactly the shape the lowering layer executes: one accelerator
//! program (or host glue step) at a time with host-managed transfers.

use std::collections::HashMap;

use crate::mapping::conv::Conv2d;
use crate::mapping::gemm::{gemm_ref, GemmParams};
use crate::mapping::rowwise::{addmat_ref, gelu_ref, layernorm_ref, softmax_ref, transpose_ref};

/// The causal-mask fill value.  `(NEG_MASK - max).exp()` underflows to
/// exactly +0.0 for any finite row maximum, so masked positions
/// contribute bitwise nothing to the softmax row sum or the subsequent
/// `P·V` accumulation — the KV-cache decode oracle's bit-exactness
/// (incremental decode ≡ from-scratch prefill of the extended sequence)
/// rests on this.
pub const NEG_MASK: f32 = -1e30;

/// Host-side 2×2 max-pool on batch × (c·h·w) channel-major activations —
/// the single implementation shared by the reference forward pass and the
/// lowered-schedule runner (`dnn::lowering`), so the two can't drift.
pub(crate) fn maxpool2x2(act: &[f32], batch: usize, c: usize, h: usize, w: usize) -> Vec<f32> {
    let (oh, ow) = (h / 2, w / 2);
    let (in_feat, out_feat) = (c * h * w, c * oh * ow);
    let mut out = vec![0.0f32; batch * out_feat];
    for bi in 0..batch {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            m = m.max(
                                act[bi * in_feat
                                    + ch * h * w
                                    + (oy * 2 + dy) * w
                                    + (ox * 2 + dx)],
                            );
                        }
                    }
                    out[bi * out_feat + ch * oh * ow + oy * ow + ox] = m;
                }
            }
        }
    }
    out
}

/// One layer of a sequential model.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// Fully connected: `out = act(x · W + b)`, W is in×out.
    Dense {
        in_features: usize,
        out_features: usize,
        relu: bool,
    },
    /// 2-D convolution followed by optional ReLU (lowered via im2col).
    Conv2d { conv: Conv2d, relu: bool },
    /// 2×2 max-pool halving H and W (lowered on the host between
    /// accelerator calls, like TVM's layout-transform glue).
    MaxPool2x2,
    Flatten,
    // ----- transformer layers (activation matrix is rows × features) ---
    /// Activation-×-activation matrix multiply: `act · stash[slot]`,
    /// scaled by `scale` (attention's `Q·K^T / √d` and `P·V`).  The
    /// stashed operand must be `features × n`-shaped at run time.
    MatMul { slot: usize, scale: f32 },
    /// Row-wise numerically stable softmax over the feature axis.
    Softmax,
    /// Row-wise non-affine layer normalization over the feature axis.
    LayerNorm { eps: f32 },
    /// Element-wise GELU (tanh approximation).
    Gelu,
    /// Residual connection: `act += stash[slot]` (same shape).
    AddResidual { slot: usize },
    /// Transpose the activation matrix (`rows × features` →
    /// `features × rows`) — attention's `K^T` data movement.
    Transpose,
    /// Save the current activation into numbered slot `slot`.
    Stash { slot: usize },
    /// Restore the activation saved in slot `slot`.
    Recall { slot: usize },
    /// Append the current activation's rows to numbered slot `slot`,
    /// creating it when absent — the KV-cache write.  Pass-through: the
    /// running activation is unchanged.  Lowering can seed the slot to a
    /// pre-existing cache shape, which is how one graph serves both the
    /// prefill and decode phases.
    AppendStash { slot: usize },
    /// Activation × stashed-activation**T** matrix multiply:
    /// `act · stash[slot]^T`, scaled by `scale` — attention's `Q·K^T/√d`
    /// against a **row-major** K cache (`n × features` at run time), so
    /// the cache appends one row per decoded token without a transpose.
    MatMulT { slot: usize, scale: f32 },
    /// Causal attention mask (host step): with `off = cols − rows`, set
    /// entries `j > i + off` of row `i` to [`NEG_MASK`], so softmax sends
    /// them to exactly +0.0.  At prefill (`rows == cols`) this is the
    /// strict upper triangle; at decode (`rows == 1`) it masks nothing —
    /// the newest token attends over the whole cache.
    CausalMask,
}

/// A sequential DNN: input shape + layers + deterministic parameters.
#[derive(Debug, Clone)]
pub struct DnnGraph {
    /// Flattened input feature count (batch comes from the workload).
    pub input_features: usize,
    pub layers: Vec<Layer>,
    pub name: String,
}

impl DnnGraph {
    /// The E9 end-to-end model: 784-256-128-10 MLP (hidden ReLU).
    pub fn mlp_784_256_128_10() -> Self {
        DnnGraph {
            input_features: 784,
            layers: vec![
                Layer::Dense {
                    in_features: 784,
                    out_features: 256,
                    relu: true,
                },
                Layer::Dense {
                    in_features: 256,
                    out_features: 128,
                    relu: true,
                },
                Layer::Dense {
                    in_features: 128,
                    out_features: 10,
                    relu: false,
                },
            ],
            name: "mlp_784_256_128_10".into(),
        }
    }

    /// A small CNN (1×8×8 input): Conv2d(1→4, 3×3, pad 1) + ReLU →
    /// MaxPool2×2 → Flatten → Dense(64→10).  Exercises the im2col path
    /// end-to-end while staying fast enough for tests.
    pub fn cnn_small() -> Self {
        DnnGraph {
            input_features: 64,
            layers: vec![
                Layer::Conv2d {
                    conv: Conv2d {
                        in_c: 1,
                        in_h: 8,
                        in_w: 8,
                        out_c: 4,
                        k_h: 3,
                        k_w: 3,
                        stride: 1,
                        pad: 1,
                    },
                    relu: true,
                },
                Layer::MaxPool2x2,
                Layer::Flatten,
                Layer::Dense {
                    in_features: 64,
                    out_features: 10,
                    relu: false,
                },
            ],
            name: "cnn_small".into(),
        }
    }

    /// A single-head, single-block transformer over `d = 16` token
    /// features: embed → pre-norm self-attention (Q·K^T/√d softmax · V,
    /// output projection, residual) → pre-stash GELU FFN (16→32→16,
    /// residual) → final norm → 8-class head.  The *batch* of the
    /// workload is the **sequence length** (one token per activation
    /// row); every GeMM dimension is a multiple of 8, so the model runs
    /// unpadded on Γ̈'s 8×8 MXU whenever the sequence length is too.
    ///
    /// This is the first non-matmul-only dataflow in the zoo: it
    /// exercises `MatMul` over stashed activations, `Transpose`,
    /// `Softmax`, `LayerNorm`, `Gelu`, and residual `AddResidual` —
    /// lowered through the same registry seam as everything else.
    pub fn tiny_transformer() -> Self {
        const D: usize = 16;
        const FFN: usize = 32;
        const OUT: usize = 8;
        const EPS: f32 = 1e-5;
        let dense = |i: usize, o: usize| Layer::Dense {
            in_features: i,
            out_features: o,
            relu: false,
        };
        DnnGraph {
            input_features: D,
            layers: vec![
                dense(D, D),                   // 0: embed
                Layer::LayerNorm { eps: EPS }, // 1: pre-attention norm
                Layer::Stash { slot: 0 },      // 2: x
                dense(D, D),                   // 3: K = x·Wk
                Layer::Transpose,              // 4: K^T (d × T)
                Layer::Stash { slot: 1 },      // 5
                Layer::Recall { slot: 0 },     // 6
                dense(D, D),                   // 7: V = x·Wv
                Layer::Stash { slot: 2 },      // 8
                Layer::Recall { slot: 0 },     // 9
                dense(D, D),                   // 10: Q = x·Wq
                Layer::MatMul {
                    slot: 1,
                    scale: 0.25, // 1/√16
                },                             // 11: S = Q·K^T/√d (T × T)
                Layer::Softmax,                // 12: P = softmax(S)
                Layer::MatMul {
                    slot: 2,
                    scale: 1.0,
                },                             // 13: ctx = P·V (T × d)
                dense(D, D),                   // 14: output projection
                Layer::AddResidual { slot: 0 }, // 15: + x
                Layer::LayerNorm { eps: EPS }, // 16
                Layer::Stash { slot: 3 },      // 17: y
                dense(D, FFN),                 // 18: FFN up
                Layer::Gelu,                   // 19
                dense(FFN, D),                 // 20: FFN down
                Layer::AddResidual { slot: 3 }, // 21: + y
                Layer::LayerNorm { eps: EPS }, // 22: final norm
                dense(D, OUT),                 // 23: head
            ],
            name: "tiny_transformer".into(),
        }
    }

    /// A parameterized **causal** transformer: `layers` pre-norm blocks
    /// of `heads`-head self-attention over `d = 16` token features with a
    /// KV cache (per-head K/V slots written via [`Layer::AppendStash`]),
    /// each block closed by the same GELU FFN as
    /// [`Self::tiny_transformer`], then a final norm and 8-class head.
    ///
    /// One graph serves both serving phases: lowered at `batch = seq`
    /// with empty slots it is the **prefill** schedule; lowered at
    /// `batch = 1` with the K/V slots seeded to the cache shape it is one
    /// **decode** step (`dnn::lowering::lower_serving`).
    /// [`Layer::CausalMask`] keeps every prefix row independent of later
    /// tokens, which is what makes incremental KV-cached decode
    /// bit-identical to a from-scratch prefill of the extended sequence.
    ///
    /// `heads` must divide 16.  Each head projects to `16/heads`
    /// features, attends causally, projects back to 16, and the per-head
    /// projections are summed — mathematically the concat-then-project
    /// formulation with the projection matrix sliced per head.
    pub fn transformer(layers: usize, heads: usize) -> Self {
        const D: usize = 16;
        const FFN: usize = 32;
        const OUT: usize = 8;
        const EPS: f32 = 1e-5;
        assert!(layers >= 1, "transformer needs at least one layer");
        assert!(heads >= 1 && D % heads == 0, "heads must divide {D}");
        let dh = D / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let dense = |i: usize, o: usize| Layer::Dense {
            in_features: i,
            out_features: o,
            relu: false,
        };
        let mut ls = vec![dense(D, D)]; // embed
        for l in 0..layers {
            // Per-layer slot bank: 2 K/V slots per head, then the block's
            // x / head-accumulator / FFN-residual slots.
            let base = l * (2 * heads + 4);
            let k_slot = |h: usize| base + 2 * h;
            let v_slot = |h: usize| base + 2 * h + 1;
            let x_slot = base + 2 * heads;
            let acc_slot = base + 2 * heads + 1;
            let ffn_slot = base + 2 * heads + 2;
            ls.push(Layer::LayerNorm { eps: EPS });
            ls.push(Layer::Stash { slot: x_slot });
            for h in 0..heads {
                ls.push(Layer::Recall { slot: x_slot });
                ls.push(dense(D, dh)); // K head
                ls.push(Layer::AppendStash { slot: k_slot(h) });
                ls.push(Layer::Recall { slot: x_slot });
                ls.push(dense(D, dh)); // V head
                ls.push(Layer::AppendStash { slot: v_slot(h) });
                ls.push(Layer::Recall { slot: x_slot });
                ls.push(dense(D, dh)); // Q head
                ls.push(Layer::MatMulT { slot: k_slot(h), scale });
                ls.push(Layer::CausalMask);
                ls.push(Layer::Softmax);
                ls.push(Layer::MatMul {
                    slot: v_slot(h),
                    scale: 1.0,
                });
                ls.push(dense(dh, D)); // per-head output projection
                if heads > 1 {
                    if h == 0 {
                        ls.push(Layer::Stash { slot: acc_slot });
                    } else {
                        ls.push(Layer::AddResidual { slot: acc_slot });
                        if h < heads - 1 {
                            ls.push(Layer::Stash { slot: acc_slot });
                        }
                    }
                }
            }
            ls.push(Layer::AddResidual { slot: x_slot });
            ls.push(Layer::LayerNorm { eps: EPS });
            ls.push(Layer::Stash { slot: ffn_slot });
            ls.push(dense(D, FFN));
            ls.push(Layer::Gelu);
            ls.push(dense(FFN, D));
            ls.push(Layer::AddResidual { slot: ffn_slot });
        }
        ls.push(Layer::LayerNorm { eps: EPS });
        ls.push(dense(D, OUT));
        DnnGraph {
            input_features: D,
            layers: ls,
            name: format!("transformer_l{layers}_h{heads}"),
        }
    }

    /// A small MLP for fast tests.
    pub fn mlp_small() -> Self {
        DnnGraph {
            input_features: 16,
            layers: vec![
                Layer::Dense {
                    in_features: 16,
                    out_features: 24,
                    relu: true,
                },
                Layer::Dense {
                    in_features: 24,
                    out_features: 8,
                    relu: false,
                },
            ],
            name: "mlp_small".into(),
        }
    }

    /// Deterministic pseudo-random parameters for layer `idx`:
    /// (weights row-major in×out, bias len out).  Same scheme as the
    /// Python golden models' seeded init (xorshift over layer index).
    pub fn dense_params(&self, idx: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let Layer::Dense {
            in_features,
            out_features,
            ..
        } = self.layers.get(idx)?
        else {
            return None;
        };
        let mut s = (idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (((s >> 16) % 2001) as f32 - 1000.0) / 10_000.0 // ±0.1
        };
        let w: Vec<f32> = (0..in_features * out_features).map(|_| next()).collect();
        let b: Vec<f32> = (0..*out_features).map(|_| next()).collect();
        Some((w, b))
    }

    /// Deterministic pseudo-random OIHW weights for a Conv2d layer `idx`
    /// (same xorshift-over-layer-index scheme as [`Self::dense_params`];
    /// conv layers carry no bias).
    pub fn conv_params(&self, idx: usize) -> Option<Vec<f32>> {
        let Layer::Conv2d { conv, .. } = self.layers.get(idx)? else {
            return None;
        };
        let mut s = (idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (((s >> 16) % 2001) as f32 - 1000.0) / 10_000.0 // ±0.1
        };
        Some(
            (0..conv.out_c * conv.in_c * conv.k_h * conv.k_w)
                .map(|_| next())
                .collect(),
        )
    }

    /// Deterministic input batch (batch × input_features).
    pub fn input_batch(&self, batch: usize) -> Vec<f32> {
        let mut s = 0xDEAD_BEEF_u64;
        (0..batch * self.input_features)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (((s >> 8) % 201) as f32 - 100.0) / 100.0
            })
            .collect()
    }

    /// Host-side reference forward pass (row-major, rows × features; rows
    /// start at `batch` and only [`Layer::Transpose`]/[`Layer::Recall`]
    /// change them).  Conv/pool stages use channel-major (C,H,W)
    /// flattening per image; the spatial shape is tracked from each conv
    /// layer's own dims.
    ///
    /// Every operator reference here computes the **same f32 operations
    /// in the same order** as the lowered scalar/GeMM programs (the
    /// accumulation runs k-sequentially from zero with bias added last,
    /// matching the device + host-epilogue order), so on targets whose
    /// GeMM accumulates sequentially (OMA, systolic) the simulated model
    /// output equals this reference *bit-for-bit*.
    pub fn forward_ref(&self, x: &[f32], batch: usize) -> Vec<f32> {
        let mut h = x.to_vec();
        let mut feat = self.input_features;
        let mut rows = batch;
        // (channels, height, width) of the current activations, when known.
        let mut shape: Option<(usize, usize, usize)> = None;
        // Stash slots: (activation, rows, features).
        let mut stash: HashMap<usize, (Vec<f32>, usize, usize)> = HashMap::new();
        for (idx, layer) in self.layers.iter().enumerate() {
            match layer {
                Layer::Dense {
                    in_features,
                    out_features,
                    relu,
                } => {
                    assert_eq!(feat, *in_features);
                    let (w, b) = self.dense_params(idx).unwrap();
                    let mut out = vec![0.0f32; rows * out_features];
                    for bi in 0..rows {
                        for o in 0..*out_features {
                            let mut acc = 0.0f32;
                            for i in 0..*in_features {
                                acc += h[bi * in_features + i] * w[i * out_features + o];
                            }
                            acc += b[o];
                            out[bi * out_features + o] = if *relu { acc.max(0.0) } else { acc };
                        }
                    }
                    h = out;
                    feat = *out_features;
                    shape = None;
                }
                Layer::Conv2d { conv, relu } => {
                    assert_eq!(rows, batch, "conv layers run on the full batch");
                    assert_eq!(
                        feat,
                        conv.in_c * conv.in_h * conv.in_w,
                        "conv input shape mismatch at layer {idx}"
                    );
                    let w = self.conv_params(idx).unwrap();
                    let (oh, ow) = (conv.out_h(), conv.out_w());
                    let out_feat = conv.out_c * oh * ow;
                    let mut out = vec![0.0f32; batch * out_feat];
                    for bi in 0..batch {
                        let img = &h[bi * feat..(bi + 1) * feat];
                        let mut y = conv.conv_ref(img, &w);
                        if *relu {
                            for v in &mut y {
                                *v = v.max(0.0);
                            }
                        }
                        out[bi * out_feat..(bi + 1) * out_feat].copy_from_slice(&y);
                    }
                    h = out;
                    feat = out_feat;
                    shape = Some((conv.out_c, oh, ow));
                }
                Layer::MaxPool2x2 => {
                    let (c, ih, iw) = shape.expect("pool needs a known spatial shape");
                    h = maxpool2x2(&h, batch, c, ih, iw);
                    feat = c * (ih / 2) * (iw / 2);
                    shape = Some((c, ih / 2, iw / 2));
                }
                Layer::Flatten => {
                    // (C,H,W) is already flattened channel-major.
                    shape = None;
                }
                Layer::MatMul { slot, scale } => {
                    let (b, brows, bcols) = stash
                        .get(slot)
                        .unwrap_or_else(|| panic!("matmul at layer {idx}: empty slot {slot}"));
                    assert_eq!(feat, *brows, "matmul operand shapes at layer {idx}");
                    let p = GemmParams::new(rows, feat, *bcols);
                    h = gemm_ref(&p, &h, b);
                    for v in &mut h {
                        *v *= scale;
                    }
                    feat = *bcols;
                    shape = None;
                }
                Layer::Softmax => h = softmax_ref(rows, feat, &h),
                Layer::LayerNorm { eps } => h = layernorm_ref(rows, feat, *eps, &h),
                Layer::Gelu => h = gelu_ref(&h),
                Layer::AddResidual { slot } => {
                    let (b, brows, bcols) = stash
                        .get(slot)
                        .unwrap_or_else(|| panic!("residual at layer {idx}: empty slot {slot}"));
                    assert_eq!((rows, feat), (*brows, *bcols), "residual shape at layer {idx}");
                    h = addmat_ref(&h, b);
                }
                Layer::Transpose => {
                    h = transpose_ref(rows, feat, &h);
                    std::mem::swap(&mut rows, &mut feat);
                    shape = None;
                }
                Layer::Stash { slot } => {
                    stash.insert(*slot, (h.clone(), rows, feat));
                }
                Layer::AppendStash { slot } => match stash.get_mut(slot) {
                    Some((v, r, c)) => {
                        assert_eq!(*c, feat, "append width at layer {idx}");
                        v.extend_from_slice(&h);
                        *r += rows;
                    }
                    None => {
                        stash.insert(*slot, (h.clone(), rows, feat));
                    }
                },
                Layer::MatMulT { slot, scale } => {
                    let (b, brows, bcols) = stash
                        .get(slot)
                        .unwrap_or_else(|| panic!("matmult at layer {idx}: empty slot {slot}"));
                    assert_eq!(feat, *bcols, "matmult operand shapes at layer {idx}");
                    let bt = transpose_ref(*brows, *bcols, b);
                    let p = GemmParams::new(rows, feat, *brows);
                    h = gemm_ref(&p, &h, &bt);
                    for v in &mut h {
                        *v *= scale;
                    }
                    feat = *brows;
                    shape = None;
                }
                Layer::CausalMask => {
                    assert!(rows <= feat, "causal mask needs rows ≤ cols at layer {idx}");
                    let off = feat - rows;
                    for i in 0..rows {
                        for v in &mut h[i * feat + i + off + 1..(i + 1) * feat] {
                            *v = NEG_MASK;
                        }
                    }
                }
                Layer::Recall { slot } => {
                    let (v, r, c) = stash
                        .get(slot)
                        .unwrap_or_else(|| panic!("recall at layer {idx}: empty slot {slot}"))
                        .clone();
                    h = v;
                    rows = r;
                    feat = c;
                    shape = None;
                }
            }
        }
        h
    }

    pub fn parameter_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Dense {
                    in_features,
                    out_features,
                    ..
                } => in_features * out_features + out_features,
                Layer::Conv2d { conv, .. } => {
                    conv.out_c * conv.in_c * conv.k_h * conv.k_w
                }
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_shapes_and_params() {
        let g = DnnGraph::mlp_784_256_128_10();
        assert_eq!(g.parameter_count(), 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10);
        let (w, b) = g.dense_params(0).unwrap();
        assert_eq!(w.len(), 784 * 256);
        assert_eq!(b.len(), 256);
        // Deterministic.
        assert_eq!(g.dense_params(0).unwrap().0[..8], w[..8]);
    }

    #[test]
    fn cnn_forward_ref_runs() {
        let g = DnnGraph::cnn_small();
        let x = g.input_batch(2);
        let y = g.forward_ref(&x, 2);
        assert_eq!(y.len(), 2 * 10);
        assert!(y.iter().any(|&v| v != 0.0));
        // Conv weights are deterministic and the right size.
        let w = g.conv_params(0).unwrap();
        assert_eq!(w.len(), 36); // out_c 4 × in_c 1 × 3 × 3
        assert_eq!(g.conv_params(0).unwrap()[..4], w[..4]);
        assert!(g.conv_params(1).is_none(), "maxpool has no conv params");
    }

    #[test]
    fn tiny_transformer_forward_ref_runs() {
        let g = DnnGraph::tiny_transformer();
        let t = 8; // sequence length = workload batch
        let x = g.input_batch(t);
        let y = g.forward_ref(&x, t);
        assert_eq!(y.len(), t * 8, "8-class head per token");
        assert!(y.iter().all(|v| v.is_finite()));
        assert!(y.iter().any(|&v| v != 0.0));
        // Deterministic (parameters and input are seeded).
        assert_eq!(g.forward_ref(&x, t), y);
        // Every dense layer has parameters; glue layers have none.
        assert!(g.dense_params(0).is_some() && g.dense_params(23).is_some());
        assert!(g.dense_params(12).is_none(), "softmax has no parameters");
        // Sequence length is a free workload knob (non-multiple-of-8 too).
        let y6 = g.forward_ref(&g.input_batch(6), 6);
        assert_eq!(y6.len(), 6 * 8);
    }

    #[test]
    fn forward_ref_runs() {
        let g = DnnGraph::mlp_small();
        let x = g.input_batch(4);
        let y = g.forward_ref(&x, 4);
        assert_eq!(y.len(), 4 * 8);
        assert!(y.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn parameterized_transformer_forward_ref_runs() {
        for (layers, heads) in [(1, 1), (1, 2), (2, 2), (2, 4), (1, 16)] {
            let g = DnnGraph::transformer(layers, heads);
            let t = 5;
            let x = g.input_batch(t);
            let y = g.forward_ref(&x, t);
            assert_eq!(y.len(), t * 8, "l{layers} h{heads}: 8-class head per token");
            assert!(y.iter().all(|v| v.is_finite()));
            assert!(y.iter().any(|&v| v != 0.0));
            assert_eq!(g.name, format!("transformer_l{layers}_h{heads}"));
        }
        // Distinct shapes are genuinely different models.
        let a = DnnGraph::transformer(1, 1);
        let b = DnnGraph::transformer(2, 2);
        let x = a.input_batch(4);
        assert_ne!(a.forward_ref(&x, 4), b.forward_ref(&x, 4));
    }

    #[test]
    #[should_panic(expected = "heads must divide")]
    fn transformer_rejects_indivisible_heads() {
        DnnGraph::transformer(1, 3);
    }

    #[test]
    fn causal_mask_makes_prefix_outputs_stable() {
        // The bit-exactness argument behind the KV-cache oracle: masked
        // tail scores underflow to exactly +0.0 through softmax, so a
        // prefix's outputs never change when more tokens are appended —
        // bitwise, not approximately.
        let g = DnnGraph::transformer(2, 2);
        let full = g.input_batch(6);
        let y6 = g.forward_ref(&full, 6);
        let y4 = g.forward_ref(&full[..4 * g.input_features], 4);
        assert_eq!(y4, y6[..4 * 8], "prefix rows are bitwise stable");
    }

    #[test]
    fn append_stash_accumulates_rows_in_forward_ref() {
        // A graph that appends the running activation twice: the second
        // matmult sees a 2·rows-deep cache.
        let g = DnnGraph {
            input_features: 4,
            layers: vec![
                Layer::AppendStash { slot: 0 },
                Layer::AppendStash { slot: 0 },
                Layer::MatMulT { slot: 0, scale: 1.0 },
            ],
            name: "append".into(),
        };
        let x = g.input_batch(3);
        let y = g.forward_ref(&x, 3);
        assert_eq!(y.len(), 3 * 6, "3 rows × (2·3 cached rows)");
    }
}
