//! DNN graph IR and its lowering to accelerator operator schedules (§5's
//! end-to-end path: DNN → operators → ACADL instructions → simulation).

pub mod graph;
pub mod lowering;

pub use graph::{DnnGraph, Layer};
pub use lowering::{
    lower_graph, partition_graph, run_schedule, run_step, LoweredGraph, PlatformPlan,
    ScheduleReport, StageSchedule, StepCtx,
};
