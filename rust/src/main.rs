//! `acadl-cli` — the command-line front-end: parse and format ACADL
//! descriptions, validate models, map operators, run simulations and
//! sweeps, serve jobs over TCP, and execute golden-model artifacts.
//!
//! Argument parsing is hand-rolled (`--key value` flags after a
//! subcommand) — the offline build has no clap (DESIGN.md §Substitutions).
//! Each subcommand declares the flags it accepts; anything else is
//! rejected with the expected list instead of being silently ignored.

use std::collections::HashMap;

use acadl::adl;
use acadl::coordinator::{self, JobSpec, PlatformSpec, SimModeSpec, TargetSpec, Workload};
use acadl::mapping::gemm::GemmParams;
use acadl::mapping::uma::{self, Operator};
use acadl::metrics::Table;
use acadl::runtime::Golden;
use acadl::sim::BackendKind;

const USAGE: &str = "\
acadl-cli — ACADL: model AI hardware accelerators, map DNN operators, simulate

USAGE: acadl-cli <COMMAND> [--flag value]...

COMMANDS:
  parse <file.acadl>
      Parse + elaborate an ACADL description: print line:col diagnostics
      on error, otherwise its AG summary, target binding, and param axes.
  fmt <file.acadl> [--check true]
      Print the canonical form of a description.  With --check true,
      exit nonzero unless the file is already canonical (the CI golden).
  validate --target <oma|systolic|gamma> [--rows N --cols N --units N]
           | --arch-file <file.acadl>
      Build an architecture model and print its AG summary.  With
      --arch-file, elaborate the description instead (and, when it has a
      `targets` binding, verify the graph matches the built machine).
  map --target <oma|systolic|gamma> [--m N --k N --n N --tile N --head N]
      [--arch-file <file.acadl>]
      Lower a GeMM and print the disassembly head.
  simulate --target <oma|systolic|gamma> [--workload gemm|mlp|transformer]
           [--m/--k/--n N] [--tile N] [--seq N]
           [--layers N] [--heads N] [--decode-steps N]
           [--mode functional|timed|estimate] [--backend cycle|event|parallel]
           [--rows/--cols/--units N] [--arch-file <file.acadl>]
           [--platform CHIPS] [--hop-latency N] [--microbatches N]
           [--threads N] [--jobs N] [--deadline-ms N]
           [--trace <file.json>] [--stats-json <file.json>]
      Simulate a workload, print the result row as JSON.  `gemm` takes
      --m/--k/--n/--tile; `mlp` and `transformer` take --seq (batch rows /
      sequence length).  `transformer` additionally takes --layers and
      --heads (model shape; heads must divide the model width 16) and
      --decode-steps: a nonzero --decode-steps makes the run a *serving*
      scenario — a prefill over the --seq prompt populates per-layer KV
      caches, then each decode step runs one token against the growing
      cache — and the result row gains `prefill_cycles` plus
      `cycles_per_token` (decode cycles ÷ decoded tokens, the serving
      latency headline; see examples/README.md for a walkthrough).
      The timing backends report identical cycles;
      `event` skips idle cycles (faster on memory-bound workloads).
      --trace writes a Chrome-trace JSON span timeline of the (timed) run
      (open it at https://ui.perfetto.dev); --stats-json writes the full
      simulation statistics as stable-schema JSON.  Both observe without
      perturbing: cycle counts are identical with or without them.
      --platform CHIPS shards a layered workload across CHIPS copies of
      the target connected by a fabric (--hop-latency cycles per hop)
      and pipelines --microbatches inferences through the stages on
      --threads worker threads (0 = lease from the --jobs budget); any
      thread count reports identical cycles.  An --arch-file with a
      `platform { … }` block sets the same knobs from the description.
      --deadline-ms bounds the simulation's wall clock: an over-budget
      run stops within one check interval and reports a structured
      `deadline exceeded` error instead of running away.
  trace --out <file.json> [--stats-json <file.json>]
        [--target … | --arch-file <file.acadl>] [--workload gemm|mlp|transformer]
        [--m/--k/--n/--tile/--seq N] [--layers/--heads/--decode-steps N]
        [--backend cycle|event|parallel]
        [--platform CHIPS] [--hop-latency N] [--microbatches N] [--threads N]
        [--jobs N] [--deadline-ms N]
      Run a timed simulation and write its structured trace as Chrome-trace
      JSON to --out: per-FU instruction spans, per-storage-port transaction
      and DRAM-burst spans, and stall/occupancy counter tracks — load the
      file at https://ui.perfetto.dev (or chrome://tracing).  Platform jobs
      emit one track group per chip plus the fabric/DRAM timeline.  Takes
      the same workload/target/platform flags as `simulate` (always timed);
      --stats-json additionally dumps the run's statistics.
  sweep [--dim N] [--workers N] [--backend cycle|event|parallel] [--jobs N]
      Systolic design-space sweep (2x2..16x16) on an N³ GeMM.
  dse [--dim N] [--workers N] [--jobs N] [--quick true] [--no-prune true]
      [--max-edge N] [--max-units N] [--arch-file <file.acadl>]
      [--window N] [--max-points N] [--stop-after N]
      [--checkpoint <file> [--checkpoint-every N]] [--resume <file>]
      Full design-space exploration on an N³ GeMM: stream the candidates
      lazily (one --window at a time, so memory stays bounded for
      million-candidate spaces), prune with the analytical roofline
      bound and feasibility checks, evaluate survivors in parallel with
      bounded memoization, print the cycles-vs-area Pareto frontier and
      the pruning/cache statistics.  With --arch-file, the space is the
      file's `param` block cross-product, stamped incrementally from a
      single elaboration.  --checkpoint writes sweep state every
      --checkpoint-every processed candidates (atomic JSON); --resume
      continues from such a file; --stop-after ends the run at the next
      window boundary (interruptible / sharded sweeps); --max-points
      bounds the non-frontier rows kept for the report table.  The
      built-in space also runs sibling transformer sweeps — one pruned
      exploration per serving shape, with prefill-cycles and
      cycles-per-token columns for decode shapes — and sweeps 1/2/4-chip
      platforms over the sharded transformer (the cycles-vs-chips Pareto
      axis).
  serve [--addr HOST:PORT] [--workers N] [--jobs N] [--arch-file <file.acadl>]
        [--max-connections N] [--queue-depth N] [--idle-timeout-ms N]
        [--deadline-ms N]
      Serve JobSpec JSON lines over TCP.  Jobs may inline ADL text as
      {\"kind\":\"adl\",\"source\":\"…\"} targets; --arch-file pre-builds
      (and verifies) one description into the machine cache.  The server
      is supervised: job panics become error rows, a client disconnect
      cancels its in-flight simulation, and a spec's `deadline_ms`
      (defaulted by --deadline-ms) bounds its wall clock.  Load beyond
      --max-connections concurrent clients or --queue-depth waiting
      requests is shed with an explicit `overloaded` error line;
      connections idle (or trickling one line) longer than
      --idle-timeout-ms are closed (0 = never).
  golden <name> [--dir artifacts]
      Run a golden-model artifact with synthetic inputs.
";

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

/// Flags each subcommand accepts; anything else is an error.
fn allowed_flags(cmd: &str) -> &'static [&'static str] {
    match cmd {
        "validate" => &["target", "rows", "cols", "units", "arch-file"],
        "map" => &[
            "target", "rows", "cols", "units", "m", "k", "n", "tile", "head", "arch-file",
        ],
        "simulate" => &[
            "target", "rows", "cols", "units", "m", "k", "n", "tile", "mode", "backend",
            "arch-file", "workload", "seq", "layers", "heads", "decode-steps", "platform",
            "hop-latency", "microbatches", "threads", "jobs", "deadline-ms", "trace",
            "stats-json",
        ],
        // `trace` is `simulate` locked to timed mode, with a mandatory
        // --out destination (so no --mode flag here).
        "trace" => &[
            "target", "rows", "cols", "units", "m", "k", "n", "tile", "backend",
            "arch-file", "workload", "seq", "layers", "heads", "decode-steps", "platform",
            "hop-latency", "microbatches", "threads", "jobs", "deadline-ms", "out",
            "stats-json",
        ],
        "sweep" => &["dim", "workers", "backend", "jobs"],
        "dse" => &[
            "dim",
            "workers",
            "jobs",
            "quick",
            "no-prune",
            "max-edge",
            "max-units",
            "arch-file",
            "window",
            "max-points",
            "stop-after",
            "checkpoint",
            "checkpoint-every",
            "resume",
        ],
        "serve" => &[
            "addr",
            "workers",
            "jobs",
            "arch-file",
            "max-connections",
            "queue-depth",
            "idle-timeout-ms",
            "deadline-ms",
        ],
        "golden" => &["dir"],
        "fmt" => &["check"],
        _ => &[],
    }
}

impl Args {
    fn parse(argv: &[String], allowed: &[&str]) -> Result<Self, String> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if !allowed.contains(&key) {
                    return Err(if allowed.is_empty() {
                        format!("unknown flag --{key} (this command takes no flags)")
                    } else {
                        format!(
                            "unknown flag --{key} (expected: {})",
                            allowed
                                .iter()
                                .map(|f| format!("--{f}"))
                                .collect::<Vec<_>>()
                                .join(", ")
                        )
                    });
                }
                let val = it
                    .next()
                    .ok_or_else(|| format!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad number `{v}`")),
            None => Ok(default),
        }
    }

    fn opt_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key}: bad number `{v}`")),
            None => Ok(None),
        }
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Boolean flag: absent → false; `--key true|false` parsed strictly
    /// (so `--no-prune false` means what it says instead of silently
    /// acting like `--no-prune true`).
    fn bool_flag(&self, key: &str) -> Result<bool, String> {
        match self.flags.get(key).map(String::as_str) {
            None => Ok(false),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(other) => Err(format!("--{key}: expected true|false, got `{other}`")),
        }
    }
}

fn backend_kind(args: &Args) -> Result<BackendKind, String> {
    let name = args.str("backend", "cycle");
    BackendKind::from_name(&name)
        .ok_or_else(|| format!("unknown backend `{name}` (use cycle|event|parallel)"))
}

/// Apply `--jobs N` (or leave `ACADL_JOBS` / core count in charge): the
/// single process-wide parallelism budget every pool — DSE workers, serve
/// slots, platform simulation threads — draws from, so nested parallelism
/// cannot oversubscribe the machine.
fn apply_jobs_flag(args: &Args) -> Result<(), String> {
    if let Some(j) = args.opt_usize("jobs")? {
        acadl::util::jobs::set_override(j);
    }
    Ok(())
}

/// Read + parse + elaborate an `.acadl` file, prefixing diagnostics with
/// the path.
fn load_arch_file(path: &str) -> Result<adl::ElabArch, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    adl::load_str(&src).map_err(|e| format!("{path}: {e}"))
}

/// Load an `.acadl` file once and, when it carries a `targets` binding,
/// build the bound machine through the config-hash cache and verify the
/// description's graph is equivalent to it — so the cycles a file-driven
/// run reports always belong to the architecture the text describes.
fn load_verified(path: &str) -> Result<adl::ElabArch, String> {
    let arch = load_arch_file(path)?;
    if let Some(spec) = &arch.target {
        let machine = coordinator::build_cached(spec).map_err(|e| e.to_string())?;
        adl::ag_equiv(&arch.ag, machine.ag()).map_err(|e| {
            format!("{path}: description does not match its `targets` binding: {e}")
        })?;
    }
    Ok(arch)
}

/// Resolve an `.acadl` file to its (verified) mapping target.
fn arch_file_target(path: &str) -> Result<TargetSpec, String> {
    load_verified(path)?.target.ok_or_else(|| {
        format!(
            "{path}: no `targets` binding — `parse`/`fmt`/`validate` work on the graph \
             alone, but simulate/map/dse need a code-generator family"
        )
    })
}

/// With `--arch-file`, the file defines the whole architecture: reject
/// every flag that would otherwise pick or shape a built-in target,
/// instead of silently running something other than what was asked for.
fn reject_target_flags(args: &Args) -> Result<(), String> {
    for conflicting in ["target", "rows", "cols", "units"] {
        if args.flags.contains_key(conflicting) {
            return Err(format!(
                "--{conflicting} does not apply with --arch-file (the file defines \
                 the architecture)"
            ));
        }
    }
    Ok(())
}

fn target_spec(args: &Args) -> Result<TargetSpec, String> {
    if let Some(path) = args.flags.get("arch-file") {
        reject_target_flags(args)?;
        return arch_file_target(path);
    }
    match args.str("target", "oma").as_str() {
        "oma" => Ok(TargetSpec::Oma {
            cache: true,
            mac_latency: None,
        }),
        "systolic" => Ok(TargetSpec::Systolic {
            rows: args.usize("rows", 4)?,
            cols: args.usize("cols", 4)?,
        }),
        "gamma" => Ok(TargetSpec::Gamma {
            units: args.usize("units", 2)?,
        }),
        other => Err(format!("unknown target `{other}`")),
    }
}

fn print_dse_report(report: &acadl::dse::DseReport, title: &str) {
    print!("{}", report.table(title).render());
    println!("\n{}", report.summary());
}

/// Every subcommand `run()` dispatches on.
const COMMANDS: &[&str] = &[
    "parse", "fmt", "validate", "map", "simulate", "trace", "sweep", "dse", "serve",
    "golden", "help", "--help", "-h",
];

/// `--microbatches` shares the wire decoder's bounds (1..=4096): a zero
/// pipeline depth or an absurd one is a spec error, not something to
/// clamp silently.
fn check_microbatches(mb: usize) -> Result<usize, String> {
    if !(1..=4096).contains(&mb) {
        return Err(format!("--microbatches must be within 1..=4096, got {mb}"));
    }
    Ok(mb)
}

/// Build the [`JobSpec`] that `simulate` and `trace` share from their
/// common workload/target/platform flags (`simulate` picks the mode from
/// --mode; `trace` is always timed).
fn job_spec_from_args(args: &Args, mode: SimModeSpec) -> Result<JobSpec, String> {
    let workload = match args.str("workload", "gemm").as_str() {
        "gemm" => Workload::Gemm {
            m: args.usize("m", 8)?,
            k: args.usize("k", 8)?,
            n: args.usize("n", 8)?,
            tile: args.opt_usize("tile")?,
            order: None,
        },
        "mlp" => Workload::Mlp {
            small: true,
            batch: args.usize("seq", 8)?,
        },
        "transformer" => Workload::Transformer {
            seq: args.usize("seq", 8)?,
            layers: args.usize("layers", 1)?,
            heads: args.usize("heads", 1)?,
            decode_steps: args.usize("decode-steps", 0)?,
        },
        other => {
            return Err(format!(
                "unknown workload `{other}` (use gemm|mlp|transformer)"
            ))
        }
    };
    // The same dimension bounds the JSON wire decoder enforces — a
    // degenerate --seq/--layers/--heads/--decode-steps fails here instead
    // of deep inside lowering.
    workload.validate()?;
    apply_jobs_flag(args)?;
    // --platform flags win; otherwise an --arch-file `platform` block
    // shards the file's own target.
    let platform = if let Some(chips) = args.opt_usize("platform")? {
        Some(PlatformSpec {
            chips: chips.max(1),
            hop_latency: args.usize("hop-latency", 4)? as u64,
            microbatches: check_microbatches(args.usize("microbatches", 4)?)?,
            threads: args.usize("threads", 0)?,
        })
    } else if let Some(path) = args.flags.get("arch-file") {
        match load_arch_file(path)?.platform {
            Some(d) => Some(PlatformSpec {
                chips: d.chips,
                hop_latency: args
                    .opt_usize("hop-latency")?
                    .map_or(d.fabric.hop_latency, |h| h as u64),
                microbatches: check_microbatches(
                    args.opt_usize("microbatches")?.unwrap_or(d.microbatches),
                )?,
                threads: args.usize("threads", 0)?,
            }),
            None => None,
        }
    } else {
        None
    };
    Ok(JobSpec {
        id: 0,
        target: target_spec(args)?,
        workload,
        mode,
        backend: backend_kind(args)?,
        max_cycles: 500_000_000,
        platform,
        deadline_ms: args.opt_usize("deadline-ms")?.map(|n| n as u64),
    })
}

/// Execute a job, optionally writing its Chrome-trace timeline and/or
/// stats JSON next to the printed result row.  Without capture paths this
/// is plain [`coordinator::job::execute`] (error rows still print as
/// JSON); with capture, a failed simulation becomes a CLI error because
/// there is nothing trustworthy to write.
fn run_with_capture(
    spec: &JobSpec,
    trace_path: Option<&str>,
    stats_path: Option<&str>,
) -> Result<coordinator::JobResult, String> {
    if trace_path.is_none() && stats_path.is_none() {
        return Ok(coordinator::job::execute(spec));
    }
    if spec.mode != SimModeSpec::Timed {
        return Err(
            "--trace/--stats-json need timed mode (the functional and estimate \
             paths have no timing state to observe)"
            .into(),
        );
    }
    if stats_path.is_some() && spec.platform.is_some() {
        return Err(
            "--stats-json covers single-chip jobs; platform runs aggregate at the \
             stage level — use --trace for the per-chip timeline"
            .into(),
        );
    }
    let mut cap = coordinator::job::RunCapture {
        want_trace: trace_path.is_some(),
        ..Default::default()
    };
    let r = coordinator::job::execute_captured(spec, Some(&mut cap));
    if let Some(err) = &r.error {
        return Err(format!("simulation failed, nothing captured: {err}"));
    }
    if let Some(path) = trace_path {
        let json = if let Some(pt) = &cap.platform_trace {
            acadl::sim::chrome_trace_platform_json(pt)
        } else if let Some(tr) = &cap.trace {
            acadl::sim::chrome_trace_json(tr)
        } else {
            return Err("simulation completed but produced no trace (internal error)".into());
        };
        std::fs::write(path, format!("{json}\n")).map_err(|e| format!("write {path}: {e}"))?;
        eprintln!("trace written to {path} (open at https://ui.perfetto.dev)");
    }
    if let Some(path) = stats_path {
        let st = cap
            .stats
            .as_ref()
            .ok_or("simulation completed but produced no stats (internal error)")?;
        std::fs::write(path, format!("{}\n", st.to_json()))
            .map_err(|e| format!("write {path}: {e}"))?;
        eprintln!("stats written to {path}");
    }
    Ok(r)
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    // Reject unknown commands before flag validation, so a typoed command
    // reports itself rather than a misleading "takes no flags" error.
    if !COMMANDS.contains(&cmd.as_str()) {
        return Err(format!("unknown command `{cmd}`\n\n{USAGE}"));
    }
    let args = Args::parse(&argv[1..], allowed_flags(&cmd))?;
    match cmd.as_str() {
        "parse" => {
            let path = args
                .positional
                .first()
                .ok_or("parse needs a file path (acadl-cli parse <file.acadl>)")?;
            let arch = load_arch_file(path)?;
            let binding = match &arch.target {
                Some(t) => t.describe(),
                None => "unbound".to_string(),
            };
            println!("{path}: arch `{}` [{binding}] | {}", arch.name, arch.ag.summary());
            if !arch.params.is_empty() {
                let cross: usize = arch.params.iter().map(|a| a.values.len()).product();
                let axes: Vec<String> = arch
                    .params
                    .iter()
                    .map(|a| format!("{}×{}", a.key, a.values.len()))
                    .collect();
                println!("params: {} ({cross} candidates)", axes.join(" "));
            }
        }
        "fmt" => {
            let path = args
                .positional
                .first()
                .ok_or("fmt needs a file path (acadl-cli fmt <file.acadl> [--check true])")?;
            let src =
                std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
            let arch = adl::load_str(&src).map_err(|e| format!("{path}: {e}"))?;
            let canonical = adl::print_elab(&arch);
            if args.bool_flag("check")? {
                if canonical == src {
                    println!("{path}: canonical");
                } else {
                    let line = src
                        .lines()
                        .zip(canonical.lines())
                        .position(|(a, b)| a != b)
                        .map(|i| i + 1)
                        .unwrap_or_else(|| src.lines().count().min(canonical.lines().count()) + 1);
                    return Err(format!(
                        "{path}: not canonical (first difference at line {line}); \
                         run `acadl-cli fmt {path}` for the canonical text"
                    ));
                }
            } else {
                print!("{canonical}");
            }
        }
        "validate" => {
            if let Some(path) = args.flags.get("arch-file") {
                reject_target_flags(&args)?;
                let arch = load_verified(path)?;
                println!("{path}: {}", arch.ag.summary());
            } else {
                let spec = target_spec(&args)?;
                let machine = spec.to_config().build().map_err(|e| e.to_string())?;
                println!("{}: {}", spec.describe(), machine.ag().summary());
            }
        }
        "map" => {
            let spec = target_spec(&args)?;
            let machine = spec.to_config().build().map_err(|e| e.to_string())?;
            let mut p = GemmParams::new(
                args.usize("m", 8)?,
                args.usize("k", 8)?,
                args.usize("n", 8)?,
            );
            if let Some(t) = args.opt_usize("tile")? {
                p = p.with_tile(t);
            }
            let head = args.usize("head", 40)?;
            let lowered =
                uma::lower(&machine, &Operator::Gemm(p)).map_err(|e| e.to_string())?;
            println!(
                "{} gemm_{}x{}x{}: {} instructions",
                spec.describe(),
                p.m,
                p.k,
                p.n,
                lowered.program.len()
            );
            for line in lowered
                .program
                .disassemble(machine.ag())
                .lines()
                .take(head)
            {
                println!("{line}");
            }
            if lowered.program.len() > head {
                println!("… ({} more)", lowered.program.len() - head);
            }
        }
        "simulate" => {
            let mode = match args.str("mode", "timed").as_str() {
                "functional" => SimModeSpec::Functional,
                "timed" => SimModeSpec::Timed,
                "estimate" => SimModeSpec::Estimate,
                other => return Err(format!("unknown mode `{other}`")),
            };
            let spec = job_spec_from_args(&args, mode)?;
            let r = run_with_capture(
                &spec,
                args.flags.get("trace").map(String::as_str),
                args.flags.get("stats-json").map(String::as_str),
            )?;
            println!("{}", r.to_json());
        }
        "trace" => {
            let out = args.flags.get("out").cloned().ok_or(
                "trace needs --out <file.json> (the Chrome-trace destination; load it \
                 at https://ui.perfetto.dev)",
            )?;
            let spec = job_spec_from_args(&args, SimModeSpec::Timed)?;
            let r = run_with_capture(
                &spec,
                Some(&out),
                args.flags.get("stats-json").map(String::as_str),
            )?;
            println!("{}", r.to_json());
        }
        "sweep" => {
            apply_jobs_flag(&args)?;
            let dim = args.usize("dim", 64)?;
            let workers = args.usize("workers", acadl::util::jobs::configured().min(4))?;
            let backend = backend_kind(&args)?;
            let specs: Vec<JobSpec> = [2usize, 4, 8, 16]
                .into_iter()
                .enumerate()
                .map(|(id, edge)| JobSpec {
                    id: id as u64,
                    target: TargetSpec::Systolic {
                        rows: edge,
                        cols: edge,
                    },
                    workload: Workload::Gemm {
                        m: dim,
                        k: dim,
                        n: dim,
                        tile: None,
                        order: None,
                    },
                    mode: SimModeSpec::Timed,
                    backend,
                    max_cycles: 500_000_000,
                    platform: None,
                    deadline_ms: None,
                })
                .collect();
            let results = coordinator::run_jobs(specs, workers);
            let mut table = Table::new(
                &format!("systolic sweep, gemm {dim}³"),
                &["target", "cycles", "ipc", "util", "wall µs"],
            );
            for r in results {
                table.row(vec![
                    r.target,
                    r.cycles.to_string(),
                    format!("{:.2}", r.ipc),
                    format!("{:.1}%", r.utilization * 100.0),
                    r.wall_micros.to_string(),
                ]);
            }
            print!("{}", table.render());
        }
        "dse" => {
            apply_jobs_flag(&args)?;
            let dim = args.usize("dim", 32)?;
            let workers = args.usize("workers", acadl::util::jobs::configured())?;
            let prune = !args.bool_flag("no-prune")?;
            let mut cfg = acadl::dse::DseConfig::legacy(workers, prune);
            cfg.window = args.usize("window", acadl::dse::DEFAULT_WINDOW)?.max(1);
            // The CLI prints a table, so bound retained rows by default;
            // the frontier is always kept in full.
            cfg.keep_points = args.usize("max-points", 1024)?;
            cfg.stop_after = args.opt_usize("stop-after")?.map(|n| n as u64);
            if let Some(path) = args.flags.get("checkpoint") {
                cfg.checkpoint = Some(acadl::dse::CheckpointCfg {
                    path: path.clone(),
                    every: args.usize("checkpoint-every", 5000)?.max(1) as u64,
                });
            } else if args.flags.contains_key("checkpoint-every") {
                return Err("--checkpoint-every needs --checkpoint <file>".into());
            }
            let resume = match args.flags.get("resume") {
                Some(p) => Some(acadl::dse::Checkpoint::load(p)?),
                None => None,
            };
            let streaming_flags = resume.is_some()
                || cfg.checkpoint.is_some()
                || cfg.stop_after.is_some();
            if let Some(path) = args.flags.get("arch-file").cloned() {
                for conflicting in ["quick", "max-edge", "max-units"] {
                    if args.flags.contains_key(conflicting) {
                        return Err(format!(
                            "--{conflicting} does not apply with --arch-file (the file's \
                             `param` block defines the space)"
                        ));
                    }
                }
                // One load: verify the description against its binding up
                // front (the sweep itself varies the bound config), then
                // stamp candidates from the same elaboration — the file
                // is never re-parsed and the space never materialized.
                let arch = load_verified(&path)?;
                let space = acadl::dse::FileSpace::from_arch(&arch, dim)?;
                let mut src = acadl::dse::FileSource::new(&space)?;
                println!(
                    "exploring gemm {dim}³ over {} candidates from {path} on {workers} \
                     workers (prune: {}, window {})…\n",
                    space.total()?,
                    if prune { "roofline" } else { "off" },
                    cfg.window,
                );
                let report = acadl::dse::explore_source(&mut src, &cfg, resume)?;
                print_dse_report(&report, &format!("design space from {path}, gemm {dim}³"));
            } else {
                let quick = args.bool_flag("quick")?;
                let mut space = if quick {
                    acadl::dse::DseSpace::quick(dim)
                } else {
                    acadl::dse::DseSpace::standard(dim)
                };
                if let Some(e) = args.opt_usize("max-edge")? {
                    space.max_edge = e;
                }
                if let Some(u) = args.opt_usize("max-units")? {
                    space.max_units = u;
                }
                println!(
                    "exploring gemm {dim}³ over {} candidates on {workers} workers (prune: {})…\n",
                    space.total(),
                    if prune { "roofline" } else { "off" },
                );
                let report = acadl::dse::explore_source(
                    &mut acadl::dse::SpaceSource::new(&space),
                    &cfg,
                    resume,
                )?;
                print_dse_report(&report, &format!("design space, gemm {dim}³ (timed)"));
                // Sibling sweep: the same architecture axes on the
                // transformer workload, one exploration per serving shape
                // (separate explorations — the pruning incumbent must not
                // cross workloads, and the cheap prefill-only shape would
                // otherwise cut every decode candidate).  Serving shapes
                // report prefill cycles and cycles-per-decoded-token as
                // their own table columns.  Skipped when
                // checkpoint/resume/stop-after target the GeMM sweep:
                // those runs want exactly one interruptible sweep.
                let tf = space.enumerate_transformer();
                if !tf.is_empty() && !streaming_flags {
                    let mut groups: Vec<Vec<JobSpec>> = Vec::new();
                    for s in tf {
                        match groups.last_mut() {
                            Some(g) if g[0].workload == s.workload => g.push(s),
                            _ => groups.push(vec![s]),
                        }
                    }
                    for group in groups {
                        let desc = group[0].workload.describe();
                        println!("\nexploring {desc} over {} candidates…\n", group.len());
                        let report = acadl::dse::explore_specs(group, workers, prune);
                        print_dse_report(&report, &format!("design space, {desc} (timed)"));
                    }
                }
                // Third sibling: chip count and fabric hop latency join
                // the axes — the sharded transformer over 1/2/4-chip
                // platforms, whose frontier is the cycles-vs-chips
                // trade-off (area scales with chips).
                let pf = space.enumerate_platform();
                if !pf.is_empty() && !streaming_flags {
                    let seq = space.transformer_seq.unwrap_or(8);
                    println!(
                        "\nexploring platform-sharded transformer (seq {seq}) over {} \
                         candidates…\n",
                        pf.len()
                    );
                    let report = acadl::dse::explore_specs(pf, workers, prune);
                    print_dse_report(
                        &report,
                        &format!(
                            "design space, platform transformer seq {seq} (cycles vs chips)"
                        ),
                    );
                }
            }
        }
        "serve" => {
            apply_jobs_flag(&args)?;
            let addr = args.str("addr", "127.0.0.1:7474");
            let workers = args.usize("workers", acadl::util::jobs::configured().min(4))?;
            if let Some(path) = args.flags.get("arch-file") {
                let spec = arch_file_target(path)?;
                println!("pre-built machine from {path}: {}", spec.describe());
            }
            let mut cfg = coordinator::server::ServeCfg::new(workers);
            cfg.max_connections = args.usize("max-connections", cfg.max_connections)?.max(1);
            cfg.queue_depth = args.usize("queue-depth", cfg.queue_depth)?;
            // 0 = never time out idle connections (legacy behavior).
            cfg.idle_timeout = match args.usize("idle-timeout-ms", 60_000)? {
                0 => None,
                ms => Some(std::time::Duration::from_millis(ms as u64)),
            };
            cfg.default_deadline_ms = args.opt_usize("deadline-ms")?.map(|n| n as u64);
            let listener =
                std::net::TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
            println!("acadl-cli serving on {addr} ({workers} workers)");
            coordinator::server::serve_with(listener, cfg).map_err(|e| e.to_string())?;
        }
        "golden" => {
            let name = args
                .positional
                .first()
                .ok_or("golden needs an artifact name")?;
            let dir = args.str("dir", "artifacts");
            let mut g = Golden::load(&dir).map_err(|e| e.to_string())?;
            let sig = g
                .signature(name)
                .ok_or_else(|| format!("unknown artifact `{name}` (have: {:?})", g.names()))?
                .clone();
            let inputs: Vec<Vec<f32>> = sig
                .args
                .iter()
                .map(|a| {
                    (0..a.elements())
                        .map(|i| (i % 7) as f32 * 0.25 - 0.75)
                        .collect()
                })
                .collect();
            let outs = g.run(name, &inputs).map_err(|e| e.to_string())?;
            for (i, o) in outs.iter().enumerate() {
                let head: Vec<f32> = o.iter().take(8).copied().collect();
                println!("result[{i}] ({} elems): {head:?}…", o.len());
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => return Err(format!("unknown command `{other}`\n\n{USAGE}")),
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn unknown_flags_rejected_with_expected_list() {
        let e = Args::parse(&argv(&["--bogus", "1"]), &["dim", "workers"]).unwrap_err();
        assert!(e.contains("unknown flag --bogus"), "{e}");
        assert!(e.contains("--dim"), "{e}");
        assert!(e.contains("--workers"), "{e}");

        let e = Args::parse(&argv(&["--check", "true"]), &[]).unwrap_err();
        assert!(e.contains("takes no flags"), "{e}");
    }

    #[test]
    fn known_flags_and_positionals_parse() {
        let a = Args::parse(
            &argv(&["file.acadl", "--dim", "8", "--workers", "2"]),
            &["dim", "workers"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["file.acadl"]);
        assert_eq!(a.usize("dim", 0).unwrap(), 8);
        assert_eq!(a.usize("workers", 0).unwrap(), 2);
        assert_eq!(a.usize("absent", 7).unwrap(), 7);
        assert_eq!(a.opt_usize("absent").unwrap(), None);
    }

    #[test]
    fn flag_value_errors() {
        let e = Args::parse(&argv(&["--dim"]), &["dim"]).unwrap_err();
        assert!(e.contains("needs a value"), "{e}");

        let a = Args::parse(&argv(&["--dim", "xyz"]), &["dim"]).unwrap();
        assert!(a.usize("dim", 0).is_err());
        assert!(a.opt_usize("dim").is_err());
    }

    #[test]
    fn bool_flags_are_strict() {
        let a = Args::parse(&argv(&["--quick", "true"]), &["quick"]).unwrap();
        assert!(a.bool_flag("quick").unwrap());
        assert!(!a.bool_flag("absent").unwrap());
        let a = Args::parse(&argv(&["--quick", "yes"]), &["quick"]).unwrap();
        assert!(a.bool_flag("quick").is_err());
    }

    #[test]
    fn per_command_allowlists_cover_documented_flags() {
        // Every command that reads a flag in run() must allow it.
        assert!(allowed_flags("simulate").contains(&"backend"));
        assert!(allowed_flags("simulate").contains(&"arch-file"));
        assert!(allowed_flags("simulate").contains(&"workload"));
        assert!(allowed_flags("simulate").contains(&"seq"));
        for f in [
            "platform",
            "hop-latency",
            "microbatches",
            "threads",
            "jobs",
            "deadline-ms",
            "trace",
            "stats-json",
            "layers",
            "heads",
            "decode-steps",
        ] {
            assert!(allowed_flags("simulate").contains(&f), "simulate misses --{f}");
        }
        // `trace` takes the simulate workload flags plus --out, but never
        // --mode (it is timed by definition) or --trace (that's --out).
        for f in [
            "out",
            "stats-json",
            "workload",
            "platform",
            "backend",
            "arch-file",
            "layers",
            "heads",
            "decode-steps",
        ] {
            assert!(allowed_flags("trace").contains(&f), "trace misses --{f}");
        }
        assert!(!allowed_flags("trace").contains(&"mode"));
        assert!(!allowed_flags("trace").contains(&"trace"));
        for c in ["sweep", "dse", "serve"] {
            assert!(allowed_flags(c).contains(&"jobs"), "{c} misses --jobs");
        }
        assert!(allowed_flags("dse").contains(&"arch-file"));
        for f in [
            "window",
            "max-points",
            "stop-after",
            "checkpoint",
            "checkpoint-every",
            "resume",
        ] {
            assert!(allowed_flags("dse").contains(&f), "dse misses --{f}");
        }
        assert!(allowed_flags("serve").contains(&"arch-file"));
        for f in [
            "max-connections",
            "queue-depth",
            "idle-timeout-ms",
            "deadline-ms",
        ] {
            assert!(allowed_flags("serve").contains(&f), "serve misses --{f}");
        }
        assert!(allowed_flags("fmt").contains(&"check"));
        assert!(allowed_flags("parse").is_empty());
        // Every command with an allowlist is a known command, so the
        // unknown-command check fires before flag validation.
        for c in [
            "parse", "fmt", "validate", "map", "simulate", "trace", "sweep", "dse", "serve",
            "golden",
        ] {
            assert!(COMMANDS.contains(&c), "{c} missing from COMMANDS");
        }
    }

    #[test]
    fn target_spec_conflicts_and_unknowns() {
        let a = Args::parse(
            &argv(&["--target", "oma", "--arch-file", "x.acadl"]),
            allowed_flags("simulate"),
        )
        .unwrap();
        let e = target_spec(&a).unwrap_err();
        assert!(e.contains("--target does not apply"), "{e}");

        // Geometry flags cannot silently lose against the file either.
        let a = Args::parse(
            &argv(&["--rows", "8", "--arch-file", "x.acadl"]),
            allowed_flags("simulate"),
        )
        .unwrap();
        let e = target_spec(&a).unwrap_err();
        assert!(e.contains("--rows does not apply"), "{e}");

        let a = Args::parse(&argv(&["--target", "tpu"]), allowed_flags("simulate")).unwrap();
        assert!(target_spec(&a).unwrap_err().contains("unknown target"));

        let a = Args::parse(&argv(&["--arch-file", "/nonexistent.acadl"]), allowed_flags("simulate"))
            .unwrap();
        assert!(target_spec(&a).unwrap_err().contains("read /nonexistent.acadl"));
    }
}
