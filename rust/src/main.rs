//! `acadl-cli` — the command-line front-end: validate models, map
//! operators, run simulations and sweeps, serve jobs over TCP, and execute
//! golden-model artifacts.
//!
//! Argument parsing is hand-rolled (`--key value` flags after a
//! subcommand) — the offline build has no clap (DESIGN.md §Substitutions).

use std::collections::HashMap;

use acadl::coordinator::{self, JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::mapping::gemm::GemmParams;
use acadl::mapping::uma::{self, Operator};
use acadl::metrics::Table;
use acadl::runtime::Golden;
use acadl::sim::BackendKind;

const USAGE: &str = "\
acadl-cli — ACADL: model AI hardware accelerators, map DNN operators, simulate

USAGE: acadl-cli <COMMAND> [--flag value]...

COMMANDS:
  validate --target <oma|systolic|gamma> [--rows N --cols N --units N]
      Build an architecture model and print its AG summary.
  map --target <oma|systolic|gamma> [--m N --k N --n N --tile N --head N]
      Lower a GeMM and print the disassembly head.
  simulate --target <oma|systolic|gamma> [--m/--k/--n N] [--tile N]
           [--mode functional|timed|estimate] [--backend cycle|event]
           [--rows/--cols/--units N]
      Simulate a GeMM, print the result row as JSON.  The timing backends
      report identical cycles; `event` skips idle cycles (faster on
      memory-bound workloads).
  sweep [--dim N] [--workers N] [--backend cycle|event]
      Systolic design-space sweep (2x2..16x16) on an N³ GeMM.
  dse [--dim N] [--workers N] [--quick true] [--no-prune true]
      [--max-edge N] [--max-units N]
      Full design-space exploration on an N³ GeMM: enumerate the
      (arch × tile × loop order × backend) candidates, prune with the
      analytical roofline bound, evaluate survivors in parallel with
      memoization, print the cycles-vs-area Pareto frontier and the
      pruning/cache statistics.
  serve [--addr HOST:PORT] [--workers N]
      Serve JobSpec JSON lines over TCP.
  golden <name> [--dir artifacts]
      Run a golden-model artifact with synthetic inputs.
";

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self, String> {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it
                    .next()
                    .ok_or_else(|| format!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad number `{v}`")),
            None => Ok(default),
        }
    }

    fn opt_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key}: bad number `{v}`")),
            None => Ok(None),
        }
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Boolean flag: absent → false; `--key true|false` parsed strictly
    /// (so `--no-prune false` means what it says instead of silently
    /// acting like `--no-prune true`).
    fn bool_flag(&self, key: &str) -> Result<bool, String> {
        match self.flags.get(key).map(String::as_str) {
            None => Ok(false),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(other) => Err(format!("--{key}: expected true|false, got `{other}`")),
        }
    }
}

fn backend_kind(args: &Args) -> Result<BackendKind, String> {
    let name = args.str("backend", "cycle");
    BackendKind::from_name(&name)
        .ok_or_else(|| format!("unknown backend `{name}` (use cycle|event)"))
}

fn target_spec(args: &Args) -> Result<TargetSpec, String> {
    match args.str("target", "oma").as_str() {
        "oma" => Ok(TargetSpec::Oma {
            cache: true,
            mac_latency: None,
        }),
        "systolic" => Ok(TargetSpec::Systolic {
            rows: args.usize("rows", 4)?,
            cols: args.usize("cols", 4)?,
        }),
        "gamma" => Ok(TargetSpec::Gamma {
            units: args.usize("units", 2)?,
        }),
        other => Err(format!("unknown target `{other}`")),
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "validate" => {
            let spec = target_spec(&args)?;
            let machine = spec.to_config().build().map_err(|e| e.to_string())?;
            println!("{}: {}", spec.describe(), machine.ag().summary());
        }
        "map" => {
            let spec = target_spec(&args)?;
            let machine = spec.to_config().build().map_err(|e| e.to_string())?;
            let mut p = GemmParams::new(
                args.usize("m", 8)?,
                args.usize("k", 8)?,
                args.usize("n", 8)?,
            );
            if let Some(t) = args.opt_usize("tile")? {
                p = p.with_tile(t);
            }
            let head = args.usize("head", 40)?;
            let lowered =
                uma::lower(&machine, &Operator::Gemm(p)).map_err(|e| e.to_string())?;
            println!(
                "{} gemm_{}x{}x{}: {} instructions",
                spec.describe(),
                p.m,
                p.k,
                p.n,
                lowered.program.len()
            );
            for line in lowered
                .program
                .disassemble(machine.ag())
                .lines()
                .take(head)
            {
                println!("{line}");
            }
            if lowered.program.len() > head {
                println!("… ({} more)", lowered.program.len() - head);
            }
        }
        "simulate" => {
            let mode = match args.str("mode", "timed").as_str() {
                "functional" => SimModeSpec::Functional,
                "timed" => SimModeSpec::Timed,
                "estimate" => SimModeSpec::Estimate,
                other => return Err(format!("unknown mode `{other}`")),
            };
            let spec = JobSpec {
                id: 0,
                target: target_spec(&args)?,
                workload: Workload::Gemm {
                    m: args.usize("m", 8)?,
                    k: args.usize("k", 8)?,
                    n: args.usize("n", 8)?,
                    tile: args.opt_usize("tile")?,
                    order: None,
                },
                mode,
                backend: backend_kind(&args)?,
                max_cycles: 500_000_000,
            };
            let r = coordinator::job::execute(&spec);
            println!("{}", r.to_json());
        }
        "sweep" => {
            let dim = args.usize("dim", 64)?;
            let workers = args.usize("workers", 4)?;
            let backend = backend_kind(&args)?;
            let specs: Vec<JobSpec> = [2usize, 4, 8, 16]
                .into_iter()
                .enumerate()
                .map(|(id, edge)| JobSpec {
                    id: id as u64,
                    target: TargetSpec::Systolic {
                        rows: edge,
                        cols: edge,
                    },
                    workload: Workload::Gemm {
                        m: dim,
                        k: dim,
                        n: dim,
                        tile: None,
                        order: None,
                    },
                    mode: SimModeSpec::Timed,
                    backend,
                    max_cycles: 500_000_000,
                })
                .collect();
            let results = coordinator::run_jobs(specs, workers);
            let mut table = Table::new(
                &format!("systolic sweep, gemm {dim}³"),
                &["target", "cycles", "ipc", "util", "wall µs"],
            );
            for r in results {
                table.row(vec![
                    r.target,
                    r.cycles.to_string(),
                    format!("{:.2}", r.ipc),
                    format!("{:.1}%", r.utilization * 100.0),
                    r.wall_micros.to_string(),
                ]);
            }
            print!("{}", table.render());
        }
        "dse" => {
            let dim = args.usize("dim", 32)?;
            let workers = args.usize(
                "workers",
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(4),
            )?;
            let quick = args.bool_flag("quick")?;
            let prune = !args.bool_flag("no-prune")?;
            let mut space = if quick {
                acadl::dse::DseSpace::quick(dim)
            } else {
                acadl::dse::DseSpace::standard(dim)
            };
            if let Some(e) = args.opt_usize("max-edge")? {
                space.max_edge = e;
            }
            if let Some(u) = args.opt_usize("max-units")? {
                space.max_units = u;
            }
            println!(
                "exploring gemm {dim}³ over {} candidates on {workers} workers (prune: {})…\n",
                space.enumerate().len(),
                if prune { "roofline" } else { "off" },
            );
            let report = acadl::dse::explore(&space, workers, prune);
            print!(
                "{}",
                report.table(&format!("design space, gemm {dim}³ (timed)")).render()
            );
            println!("\n{}", report.summary());
        }
        "serve" => {
            let addr = args.str("addr", "127.0.0.1:7474");
            let workers = args.usize("workers", 4)?;
            let listener =
                std::net::TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
            println!("acadl-cli serving on {addr} ({workers} workers)");
            coordinator::server::serve(listener, workers).map_err(|e| e.to_string())?;
        }
        "golden" => {
            let name = args
                .positional
                .first()
                .ok_or("golden needs an artifact name")?;
            let dir = args.str("dir", "artifacts");
            let mut g = Golden::load(&dir).map_err(|e| e.to_string())?;
            let sig = g
                .signature(name)
                .ok_or_else(|| format!("unknown artifact `{name}` (have: {:?})", g.names()))?
                .clone();
            let inputs: Vec<Vec<f32>> = sig
                .args
                .iter()
                .map(|a| {
                    (0..a.elements())
                        .map(|i| (i % 7) as f32 * 0.25 - 0.75)
                        .collect()
                })
                .collect();
            let outs = g.run(name, &inputs).map_err(|e| e.to_string())?;
            for (i, o) in outs.iter().enumerate() {
                let head: Vec<f32> = o.iter().take(8).copied().collect();
                println!("result[{i}] ({} elems): {head:?}…", o.len());
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => return Err(format!("unknown command `{other}`\n\n{USAGE}")),
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
