//! The built-machine cache: architecture graphs are expensive to
//! construct (hundreds of objects and edges for big systolic arrays) and
//! completely immutable once built — all simulation state lives in the
//! engine, never the `Machine`.  The coordinator used to rebuild an
//! identical graph for every job batch; this cache builds each distinct
//! target **once per process**, keyed by the canonical config hash
//! (FNV-1a over the target's canonical JSON), and hands out `Arc`s that
//! pool workers, the TCP server, and the DSE engine share freely.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::acadl_core::graph::AgError;
use crate::mapping::uma::Machine;
use crate::util::hash::fnv1a_str;

use super::job::TargetSpec;
use super::lock_unpoisoned;

/// Canonical config hash of a target: FNV-1a over its canonical JSON
/// serialization (the job wire format, so the key survives round-trips).
pub fn config_hash(target: &TargetSpec) -> u64 {
    fnv1a_str(&target.to_json().to_string())
}

struct Cache {
    map: Mutex<HashMap<u64, Arc<Machine>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

fn cache() -> &'static Cache {
    static CACHE: OnceLock<Cache> = OnceLock::new();
    CACHE.get_or_init(|| Cache {
        map: Mutex::new(HashMap::new()),
        hits: AtomicU64::new(0),
        misses: AtomicU64::new(0),
    })
}

/// Retention cap: a long-lived serving process fed an endless stream of
/// *distinct* configs (a NAS client sweeping array shapes) must not
/// accumulate machines forever.  Past the cap, misses still build and
/// return a machine — it just isn't retained.  256 machines comfortably
/// covers every sweep in-tree while bounding worst-case residency.
const MAX_CACHED_MACHINES: usize = 256;

/// Build (or fetch) the machine for `target`.  Concurrent misses on the
/// same key may both build, but only one instance is kept — the graph is
/// immutable, so either copy is equally valid; the build happens outside
/// the lock so slow constructions never serialize unrelated targets.
pub fn build_cached(target: &TargetSpec) -> Result<Arc<Machine>, AgError> {
    let c = cache();
    let key = config_hash(target);
    if let Some(m) = lock_unpoisoned(&c.map).get(&key) {
        c.hits.fetch_add(1, Ordering::Relaxed);
        return Ok(Arc::clone(m));
    }
    let built = Arc::new(target.to_config().build()?);
    c.misses.fetch_add(1, Ordering::Relaxed);
    let mut map = lock_unpoisoned(&c.map);
    if map.len() >= MAX_CACHED_MACHINES && !map.contains_key(&key) {
        return Ok(built);
    }
    let entry = map.entry(key).or_insert_with(|| Arc::clone(&built));
    Ok(Arc::clone(entry))
}

/// (hits, misses) since process start.  Monotonic — tests should assert
/// on deltas, not absolutes (the cache is process-global).
pub fn cache_stats() -> (u64, u64) {
    let c = cache();
    (
        c.hits.load(Ordering::Relaxed),
        c.misses.load(Ordering::Relaxed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_config_hits_distinct_config_misses() {
        // An exotic shape no other test uses, so the first build is a miss
        // even when the whole suite shares this process-global cache.
        let t = TargetSpec::Systolic { rows: 3, cols: 7 };
        let (_, m0) = cache_stats();
        let a = build_cached(&t).unwrap();
        let (h1, m1) = cache_stats();
        // Counters are process-global and other tests run concurrently, so
        // assert direction, not exact deltas.
        assert!(m1 > m0, "first build of a fresh config is a miss");
        let b = build_cached(&t).unwrap();
        let (h2, _) = cache_stats();
        assert!(h2 > h1, "second build hits");
        assert!(Arc::ptr_eq(&a, &b), "same machine instance shared");

        let other = TargetSpec::Systolic { rows: 7, cols: 3 };
        assert_ne!(config_hash(&t), config_hash(&other));
    }

    #[test]
    fn hash_is_stable_for_equal_specs() {
        let a = TargetSpec::Gamma { units: 2 };
        let b = TargetSpec::Gamma { units: 2 };
        assert_eq!(config_hash(&a), config_hash(&b));
    }
}
