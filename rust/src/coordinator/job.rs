//! Job descriptors and execution: one job = one (target, workload, mode)
//! evaluation producing a result row.

use crate::aidg;
use crate::arch::gamma::GammaConfig;
use crate::arch::oma::OmaConfig;
use crate::arch::platform::PlatformDesc;
use crate::arch::systolic::SystolicConfig;
use crate::dnn::graph::DnnGraph;
use crate::dnn::lowering::{self, partition_graph, ScheduleCapture, SimMode};
use crate::mapping::gemm::{gemm_ref, GemmParams, LoopOrder};
use crate::mapping::uma::{self, Machine, Operator, TargetConfig};
use crate::sim::backend::BackendKind;
use crate::sim::engine::{Engine, SimStats};
use crate::sim::functional::FunctionalSim;
use crate::sim::trace::{PlatformTrace, TraceData};
use crate::util::json::{Json, JsonError};

/// Serializable target description (the job wire format).
#[derive(Debug, Clone, PartialEq)]
pub enum TargetSpec {
    Oma {
        cache: bool,
        mac_latency: Option<u64>,
    },
    Systolic {
        rows: usize,
        cols: usize,
    },
    Gamma {
        units: usize,
    },
}

impl TargetSpec {
    pub fn to_config(&self) -> TargetConfig {
        match self {
            TargetSpec::Oma { cache, mac_latency } => {
                let mut cfg = OmaConfig::default();
                if !cache {
                    cfg.cache = None;
                }
                if let Some(l) = mac_latency {
                    cfg.mac_latency = *l;
                }
                TargetConfig::Oma(cfg)
            }
            TargetSpec::Systolic { rows, cols } => {
                TargetConfig::Systolic(SystolicConfig::new(*rows, *cols))
            }
            TargetSpec::Gamma { units } => TargetConfig::Gamma(GammaConfig::new(*units)),
        }
    }

    pub fn describe(&self) -> String {
        match self {
            TargetSpec::Oma { cache, .. } => {
                format!("oma{}", if *cache { "+cache" } else { "" })
            }
            TargetSpec::Systolic { rows, cols } => format!("systolic_{rows}x{cols}"),
            TargetSpec::Gamma { units } => format!("gamma_{units}u"),
        }
    }

    /// Silicon-area proxy for Pareto plots (MAC-equivalent units).
    pub fn area_proxy(&self) -> f64 {
        match self {
            TargetSpec::Oma { cache, .. } => 1.0 + if *cache { 0.5 } else { 0.0 },
            TargetSpec::Systolic { rows, cols } => (rows * cols) as f64,
            TargetSpec::Gamma { units } => (units * 64) as f64, // 8×8 MXU each
        }
    }

    /// The analytical roofline of this target: sound lower-bound
    /// denominators for the DSE pre-filter (see [`crate::analytical`]).
    pub fn roofline(&self) -> crate::analytical::Roofline {
        match self {
            TargetSpec::Oma { .. } => crate::analytical::Roofline::oma(),
            TargetSpec::Systolic { rows, cols } => {
                crate::analytical::Roofline::systolic(*rows, *cols)
            }
            TargetSpec::Gamma { units } => crate::analytical::Roofline::gamma(*units),
        }
    }
}

/// The workload half of a job.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    Gemm {
        m: usize,
        k: usize,
        n: usize,
        tile: Option<usize>,
        order: Option<LoopOrder>,
    },
    /// The built-in MLPs (small = tests; big = the E9 784-256-128-10).
    Mlp {
        small: bool,
        batch: usize,
    },
    /// A parameterized transformer (embed → `layers`×(MHA + FFN) → head);
    /// `seq` is the prompt length (the prefill schedule's batch — one
    /// token per row) and `heads` must divide the model width 16.  When
    /// `decode_steps > 0` the job prices the full serving loop: prefill
    /// populates per-layer K/V caches, then each decode step runs one
    /// token attending over the growing cache.  The defaults
    /// `layers=1, heads=1, decode_steps=0` reproduce the original
    /// `tiny_transformer` job bit-for-bit, including its memo identity.
    Transformer {
        seq: usize,
        layers: usize,
        heads: usize,
        decode_steps: usize,
    },
}

impl Workload {
    /// The canonical form of this workload **for a given target**:
    /// mapping parameters that cannot reach the target's code generator
    /// are normalized away so semantically identical jobs share a memo
    /// key.  Tile and loop order only affect the OMA's unrolled GeMM;
    /// on the OMA, an absent order is the generator default (`ijk`) and a
    /// tile covering every dim is the untiled program.
    pub fn canonical_for(&self, target: &TargetSpec) -> Workload {
        match self {
            Workload::Gemm { m, k, n, tile, order } => {
                let (m, k, n) = (*m, *k, *n);
                if matches!(target, TargetSpec::Oma { .. }) {
                    Workload::Gemm {
                        m,
                        k,
                        n,
                        tile: (*tile).filter(|&t| t < m.max(k).max(n)),
                        order: Some(order.unwrap_or(LoopOrder::Ijk)),
                    }
                } else {
                    Workload::Gemm {
                        m,
                        k,
                        n,
                        tile: None,
                        order: None,
                    }
                }
            }
            Workload::Mlp { small, batch } => Workload::Mlp {
                small: *small,
                batch: *batch,
            },
            Workload::Transformer { .. } => self.clone(),
        }
    }

    pub fn describe(&self) -> String {
        match self {
            Workload::Gemm { m, k, n, tile, order } => {
                let mut s = format!("gemm_{m}x{k}x{n}");
                if let Some(t) = tile {
                    s.push_str(&format!("_t{t}"));
                }
                if let Some(o) = order {
                    s.push_str(&format!("_{}", o.name()));
                }
                s
            }
            Workload::Mlp { small, batch } => {
                format!("mlp_{}_b{batch}", if *small { "small" } else { "784" })
            }
            Workload::Transformer { seq, layers, heads, decode_steps } => {
                if *layers == 1 && *heads == 1 && *decode_steps == 0 {
                    format!("tiny_transformer_s{seq}")
                } else {
                    format!("transformer_s{seq}_l{layers}_h{heads}_d{decode_steps}")
                }
            }
        }
    }

    /// Wire-boundary sanity bounds: degenerate dimensions (empty graphs,
    /// panicking constructors) and absurd ones (effectively unbounded
    /// loops) are rejected before a supervised slot is spent on them.
    /// Shared by the JSON decoder ([`Self::from_json`] →
    /// `JsonError::Invalid`) and the CLI's `job_spec_from_args`.
    pub fn validate(&self) -> Result<(), String> {
        fn bounds(name: &str, v: usize, lo: usize, hi: usize) -> Result<(), String> {
            if v < lo || v > hi {
                return Err(format!("{name} must be in {lo}..={hi}, got {v}"));
            }
            Ok(())
        }
        match self {
            Workload::Gemm { .. } => Ok(()),
            Workload::Mlp { batch, .. } => bounds("batch", *batch, 1, 4096),
            Workload::Transformer { seq, layers, heads, decode_steps } => {
                bounds("seq", *seq, 1, 1024)?;
                bounds("layers", *layers, 1, 32)?;
                if *heads < 1 || 16 % *heads != 0 {
                    return Err(format!("heads must divide the model width 16, got {heads}"));
                }
                bounds("decode_steps", *decode_steps, 0, 1024)
            }
        }
    }
}

/// A multi-accelerator platform wrapper around the job's target: `chips`
/// copies of the target behind a shared fabric + DRAM, pipelining
/// `microbatches` inferences of the (layered) workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlatformSpec {
    pub chips: usize,
    /// Per-hop fabric latency in cycles (link width stays the default).
    pub hop_latency: u64,
    pub microbatches: usize,
    /// Worker threads for the parallel simulation; `0` = lease from the
    /// process-wide `--jobs` budget.  Never part of the result identity —
    /// any thread count reports identical cycles.
    pub threads: usize,
}

impl Default for PlatformSpec {
    fn default() -> Self {
        let d = PlatformDesc::default();
        PlatformSpec {
            chips: 4,
            hop_latency: d.fabric.hop_latency,
            microbatches: d.microbatches,
            threads: 0,
        }
    }
}

impl PlatformSpec {
    pub fn desc(&self) -> PlatformDesc {
        PlatformDesc::new(self.chips)
            .with_hop_latency(self.hop_latency)
            .with_microbatches(self.microbatches)
    }

    pub fn describe(&self, target: &str) -> String {
        format!(
            "platform{}[{target}]_h{}_m{}",
            self.chips, self.hop_latency, self.microbatches
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("chips", Json::num(self.chips as f64)),
            ("hop_latency", Json::num(self.hop_latency as f64)),
            ("microbatches", Json::num(self.microbatches as f64)),
            ("threads", Json::num(self.threads as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let d = PlatformSpec::default();
        // `microbatches: 0` would silently clamp and absurd values would
        // pipeline effectively unbounded session loops — reject both at
        // the wire instead of burning a supervised slot.
        let microbatches = v.opt_u64("microbatches", d.microbatches as u64) as usize;
        if !(1..=4096).contains(&microbatches) {
            return Err(JsonError::Invalid(format!(
                "microbatches must be in 1..=4096, got {microbatches}"
            )));
        }
        Ok(PlatformSpec {
            chips: v.field("chips")?.as_usize()?.max(1),
            hop_latency: v.opt_u64("hop_latency", d.hop_latency),
            microbatches,
            threads: v.opt_u64("threads", 0) as usize,
        })
    }
}

/// Simulation mode for the job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimModeSpec {
    Functional,
    Timed,
    /// AIDG fast estimate.
    Estimate,
}

impl SimModeSpec {
    pub fn name(self) -> &'static str {
        match self {
            SimModeSpec::Functional => "functional",
            SimModeSpec::Timed => "timed",
            SimModeSpec::Estimate => "estimate",
        }
    }
}

/// One evaluation request.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: u64,
    pub target: TargetSpec,
    pub workload: Workload,
    pub mode: SimModeSpec,
    /// Timing-simulation backend (ignored by functional/estimate modes).
    /// Both backends report identical cycles; event-driven is faster on
    /// memory-bound jobs.
    pub backend: BackendKind,
    pub max_cycles: u64,
    /// `Some` shards the (layered) workload across a multi-chip platform
    /// and pipelines microbatches through it.
    pub platform: Option<PlatformSpec>,
    /// Wall-clock budget for this job in milliseconds.  `Some` installs a
    /// deadline token around execution: a simulation that outlives the
    /// budget stops cooperatively at the next check interval and reports
    /// `deadline exceeded …` instead of spinning to `max_cycles`.
    /// Excluded from [`Self::canonical_key`] — it bounds the *computation*,
    /// not the result (a completed result is valid under any budget).
    pub deadline_ms: Option<u64>,
}

pub fn default_max_cycles() -> u64 {
    200_000_000
}

/// One result row.
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub id: u64,
    pub target: String,
    pub workload: String,
    pub mode: SimModeSpec,
    pub cycles: u64,
    pub instructions: u64,
    pub ipc: f64,
    pub utilization: f64,
    /// Functional-vs-reference check (None = not applicable for mode).
    pub numerics_ok: Option<bool>,
    pub wall_micros: u64,
    pub error: Option<String>,
    pub area_proxy: f64,
    /// Serving jobs (`decode_steps > 0`) only: cycles until the prompt
    /// is fully processed (the time-to-first-token proxy).
    pub prefill_cycles: Option<u64>,
    /// Serving jobs only: mean decode cycles per generated token — the
    /// number a serving deployment actually optimizes.
    pub cycles_per_token: Option<f64>,
}

/// Coarse classification of a [`JobResult`] error string, for callers
/// that must *react* to failures (the server's reply policy, the chaos
/// harness, retry logic) without growing the wire format: the `error`
/// field stays a plain string, and classification keys off stable
/// message prefixes that the error constructors own (`SimError::Deadline`
/// / `SimError::Cancelled` in `sim::kernel`, the panic shim in
/// `coordinator::supervisor`, the server's shed reply).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobError {
    /// The job's wall-clock budget (`deadline_ms`) expired mid-run.
    Deadline,
    /// The job was cancelled (client disconnect, shutdown drain).
    Cancelled,
    /// The server shed the request before execution (admission queue full).
    Overloaded,
    /// The job body panicked; the worker caught and contained it.
    Panic,
    /// Any other failure (lowering errors, infeasibility, cycle limits…).
    Other,
}

impl JobError {
    pub fn classify(msg: &str) -> JobError {
        if msg.starts_with("deadline exceeded") {
            JobError::Deadline
        } else if msg.starts_with("cancelled") {
            JobError::Cancelled
        } else if msg.starts_with("overloaded") {
            JobError::Overloaded
        } else if msg.starts_with("panic") {
            JobError::Panic
        } else {
            JobError::Other
        }
    }
}

impl JobResult {
    /// The structured class of this result's error, if it has one.
    pub fn error_class(&self) -> Option<JobError> {
        self.error.as_deref().map(JobError::classify)
    }

    /// An error row for a job whose body panicked; the `panic: ` prefix
    /// is the classification contract ([`JobError::Panic`]).
    pub(crate) fn panicked(spec: &JobSpec, msg: String, wall_micros: u64) -> Self {
        Self::err(spec, format!("panic: {msg}"), wall_micros)
    }

    fn err(spec: &JobSpec, msg: String, wall_micros: u64) -> Self {
        JobResult {
            id: spec.id,
            target: spec.target_label(),
            workload: spec.workload.describe(),
            mode: spec.mode,
            cycles: 0,
            instructions: 0,
            ipc: 0.0,
            utilization: 0.0,
            numerics_ok: None,
            wall_micros,
            error: Some(msg),
            area_proxy: spec.area_proxy(),
            prefill_cycles: None,
            cycles_per_token: None,
        }
    }
}

/// Deterministic fault injection for the chaos harness: a job whose id
/// carries one of the chaos marks misbehaves mid-execution — but only
/// when the process opted in via `ACADL_CHAOS=1`, so no production job
/// id can ever trip it.  The faults are raised deliberately *inside*
/// the job body (after the deadline guard is installed) to exercise the
/// `catch_unwind` isolation in `pool.rs`/`server.rs`, the cancellation
/// plumbing, and the RAII unwind of slots, leases, and token guards.
/// Tests only ever *set* `ACADL_CHAOS` (never unset it), so parallel
/// tests in one binary cannot race each other's fault modes — the mark
/// bits select the behavior per job id.
pub const CHAOS_MARK_BASE: u64 = 0xC4A0_5000_0000_0000;
/// The job body panics (tests `catch_unwind` containment).
pub const CHAOS_PANIC_MARK: u64 = CHAOS_MARK_BASE | (1 << 32);
/// The job body holds its simulation slot, sleeping until its cancel
/// token trips (or a 5 s cap), then proceeds — a controllable
/// long-running job for backpressure/disconnect/deadline tests.
pub const CHAOS_STALL_MARK: u64 = CHAOS_MARK_BASE | (1 << 33);

fn chaos_armed(spec: &JobSpec, mark: u64) -> bool {
    spec.id & mark == mark && std::env::var("ACADL_CHAOS").as_deref() == Ok("1")
}

fn chaos_maybe_panic(spec: &JobSpec) {
    if chaos_armed(spec, CHAOS_PANIC_MARK) {
        panic!("chaos: injected job panic (id {:#x})", spec.id);
    }
    if chaos_armed(spec, CHAOS_STALL_MARK) {
        let token = crate::util::cancel::current();
        let start = std::time::Instant::now();
        while start.elapsed() < std::time::Duration::from_secs(5) {
            if token.as_ref().and_then(|t| t.cause()).is_some() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
}

fn gemm_inputs(p: &GemmParams) -> (Vec<f32>, Vec<f32>) {
    let mut s = 0xC0FF_EE00_u64 ^ ((p.m as u64) << 32 | (p.k as u64) << 16 | p.n as u64);
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        ((s % 13) as f32 - 6.0) / 3.0
    };
    (
        (0..p.m * p.k).map(|_| next()).collect(),
        (0..p.k * p.n).map(|_| next()).collect(),
    )
}

/// Out-of-band capture request + result for one timed job execution: the
/// CLI's `trace` / `simulate --trace|--stats-json` paths ask for the full
/// simulation statistics and (when `want_trace`) the structured span
/// trace.  Deliberately NOT part of [`JobSpec`]: capture changes what is
/// written *next to* the result, never the result itself, so it stays out
/// of the job wire format and [`JobSpec::canonical_key`] — a memoized or
/// served result remains valid whether or not anyone was watching.
#[derive(Debug, Default)]
pub struct RunCapture {
    /// Attach the span/counter recorder.  Cycle counts are unchanged —
    /// tracing is observation-only (a tested invariant).
    pub want_trace: bool,
    /// Full statistics of the run; for layered schedules this is the
    /// per-step stats merged across all mapped layers.
    pub stats: Option<SimStats>,
    /// Single-chip trace: the one engine run for a GeMM job, or the
    /// concatenated per-layer runs for a schedule job.
    pub trace: Option<TraceData>,
    /// Platform-level trace (per-chip track groups) for multi-chip jobs.
    pub platform_trace: Option<PlatformTrace>,
}

/// Execute one job on an already-built machine (the pool builds machines
/// once per target batch).
pub fn execute_on(machine: &Machine, spec: &JobSpec) -> JobResult {
    execute_on_captured(machine, spec, None)
}

/// [`execute_on`] with an optional [`RunCapture`] filled from the timed
/// simulation.  Functional/estimate runs leave the capture untouched (no
/// timing state exists to observe); callers gate on mode up front.
pub fn execute_on_captured(
    machine: &Machine,
    spec: &JobSpec,
    mut cap: Option<&mut RunCapture>,
) -> JobResult {
    let start = std::time::Instant::now();
    // A per-job deadline chains onto whatever token is already installed
    // (e.g. the server's client-disconnect watch), so either source stops
    // the simulation; the guard restores the outer token on every return
    // path, including unwinds.
    let _deadline_guard = spec.deadline_ms.map(|ms| {
        let budget = std::time::Duration::from_millis(ms);
        let token = match crate::util::cancel::current() {
            Some(outer) => outer.child_with_deadline(budget),
            None => crate::util::cancel::CancelToken::with_deadline(budget),
        };
        crate::util::cancel::install(token)
    });
    chaos_maybe_panic(spec);
    let done = |mut r: JobResult| {
        r.wall_micros = start.elapsed().as_micros() as u64;
        r
    };
    let base = JobResult {
        id: spec.id,
        target: spec.target_label(),
        workload: spec.workload.describe(),
        mode: spec.mode,
        cycles: 0,
        instructions: 0,
        ipc: 0.0,
        utilization: 0.0,
        numerics_ok: None,
        wall_micros: 0,
        error: None,
        area_proxy: spec.area_proxy(),
        prefill_cycles: None,
        cycles_per_token: None,
    };

    // Feasibility gate (same predicate the DSE pre-filter prunes on): an
    // oversized operand set would silently fall off the modeled address
    // ranges, and a bound already past the budget guarantees a cycle-limit
    // abort — fail fast, identically on every path.
    if let Some(reason) = spec.infeasible() {
        return done(JobResult::err(spec, reason, 0));
    }

    // Platform jobs shard a layered schedule across chips; a single GeMM
    // has no layer boundaries to cut, and the AIDG estimator models one
    // machine, not a fabric.
    if spec.platform.is_some() {
        if matches!(spec.workload, Workload::Gemm { .. }) {
            return done(JobResult::err(
                spec,
                "platform jobs need a layered workload (mlp|transformer)".into(),
                0,
            ));
        }
        if spec.mode == SimModeSpec::Estimate {
            return done(JobResult::err(
                spec,
                "platform jobs support functional|timed modes only".into(),
                0,
            ));
        }
    }

    match &spec.workload {
        Workload::Gemm { m, k, n, tile, order } => {
            let mut p = GemmParams::new(*m, *k, *n);
            if let Some(t) = tile {
                p = p.with_tile(*t);
            }
            if let Some(o) = order {
                p = p.with_order(*o);
            }
            // Γ̈ requires multiples of 8; pad transparently.
            if matches!(machine, Machine::Gamma(_)) {
                p.m = p.m.div_ceil(8) * 8;
                p.k = p.k.div_ceil(8) * 8;
                p.n = p.n.div_ceil(8) * 8;
            }
            let lowered = match uma::lower(machine, &Operator::Gemm(p)) {
                Ok(l) => l,
                Err(e) => {
                    return JobResult::err(spec, e.to_string(), start.elapsed().as_micros() as u64)
                }
            };
            let (a, b) = gemm_inputs(&p);
            match spec.mode {
                SimModeSpec::Functional => {
                    let mut sim = FunctionalSim::new(machine.ag());
                    lowered.layout.load_inputs(&p, &mut sim.mem, &a, &b);
                    match sim.run(&lowered.program, spec.max_cycles) {
                        Ok(st) => {
                            let got = lowered.layout.read_c(&p, &sim.mem);
                            let want = gemm_ref(&p, &a, &b);
                            let ok = got
                                .iter()
                                .zip(&want)
                                .all(|(g, w)| (g - w).abs() < 1e-2);
                            done(JobResult {
                                instructions: st.instructions,
                                numerics_ok: Some(ok),
                                ..base
                            })
                        }
                        Err(e) => done(JobResult::err(spec, e.to_string(), 0)),
                    }
                }
                SimModeSpec::Timed => {
                    let mut e = match Engine::with_backend(machine.ag(), &lowered.program, spec.backend) {
                        Ok(e) => e,
                        Err(err) => return done(JobResult::err(spec, err.to_string(), 0)),
                    };
                    if cap.as_deref().is_some_and(|c| c.want_trace) {
                        e.attach_trace();
                    }
                    lowered.layout.load_inputs(&p, &mut e.mem, &a, &b);
                    match e.run(spec.max_cycles) {
                        Ok(st) => {
                            if let Some(c) = cap.as_deref_mut() {
                                c.trace = e.take_trace();
                                c.stats = Some(st.clone());
                            }
                            let got = lowered.layout.read_c(&p, &e.mem);
                            let want = gemm_ref(&p, &a, &b);
                            let ok = got
                                .iter()
                                .zip(&want)
                                .all(|(g, w)| (g - w).abs() < 1e-2);
                            done(JobResult {
                                cycles: st.cycles,
                                instructions: st.retired,
                                ipc: st.ipc(),
                                utilization: st.mean_fu_utilization(),
                                numerics_ok: Some(ok),
                                ..base
                            })
                        }
                        Err(err) => done(JobResult::err(spec, err.to_string(), 0)),
                    }
                }
                SimModeSpec::Estimate => {
                    match aidg::estimate_fixed_point(machine.ag(), &lowered.program, spec.max_cycles)
                    {
                        Ok(est) => done(JobResult {
                            cycles: est.cycles,
                            instructions: est.instructions,
                            ipc: if est.cycles > 0 {
                                est.instructions as f64 / est.cycles as f64
                            } else {
                                0.0
                            },
                            ..base
                        }),
                        Err(err) => done(JobResult::err(spec, err.to_string(), 0)),
                    }
                }
            }
        }
        wl @ (Workload::Mlp { .. } | Workload::Transformer { .. }) => {
            let (graph, batch) = match wl {
                Workload::Mlp { small, batch } => (
                    if *small {
                        DnnGraph::mlp_small()
                    } else {
                        DnnGraph::mlp_784_256_128_10()
                    },
                    *batch,
                ),
                // The legacy shape lowers the original PR-5 graph, so its
                // schedules, cycles, and memo entries are bit-identical.
                Workload::Transformer { seq, layers: 1, heads: 1, decode_steps: 0 } => {
                    (DnnGraph::tiny_transformer(), *seq)
                }
                Workload::Transformer { seq, layers, heads, .. } => {
                    (DnnGraph::transformer(*layers, *heads), *seq)
                }
                Workload::Gemm { .. } => unreachable!("outer match"),
            };
            let decode_steps = match wl {
                Workload::Transformer { decode_steps, .. } => *decode_steps,
                _ => 0,
            };
            let mode = match spec.mode {
                SimModeSpec::Functional => SimMode::Functional,
                _ => SimMode::Timed(spec.backend),
            };
            if let Some(ps) = &spec.platform {
                // Multi-chip platform: partition the schedule, pipeline
                // microbatches, lease simulation threads from the shared
                // `--jobs` budget when the spec leaves them at auto.
                let plan = match partition_graph(&graph, batch, ps.chips) {
                    Ok(p) => p,
                    Err(e) => return done(JobResult::err(spec, e.to_string(), 0)),
                };
                let machines: Vec<&Machine> = vec![machine; plan.stages.len()];
                let desc = ps.desc();
                let lease =
                    (ps.threads == 0).then(|| crate::util::jobs::lease(desc.microbatches));
                let threads = lease.as_ref().map_or(ps.threads, |l| l.granted);
                // Platform traces come from the deterministic timing
                // recurrence, so they only exist for timed runs.
                let mut ptrace = (cap.as_deref().is_some_and(|c| c.want_trace)
                    && matches!(mode, SimMode::Timed(_)))
                .then(PlatformTrace::default);
                if decode_steps > 0 {
                    // Serving: prefill every session's prompt through the
                    // pipeline, then pipeline one-token decode phases.
                    return match crate::sim::platform::run_platform_serving(
                        &machines,
                        &graph,
                        &plan,
                        batch,
                        decode_steps,
                        &desc,
                        mode,
                        threads,
                        spec.max_cycles,
                        ptrace.as_mut(),
                    ) {
                        Ok(srep) => {
                            if let Some(c) = cap.as_deref_mut() {
                                c.platform_trace = ptrace;
                            }
                            let rep = &srep.report;
                            if rep.total_cycles > spec.max_cycles {
                                return done(JobResult::err(
                                    spec,
                                    format!(
                                        "platform makespan {} exceeds the {}-cycle budget",
                                        rep.total_cycles, spec.max_cycles
                                    ),
                                    0,
                                ));
                            }
                            let total = batch + decode_steps;
                            let ok = rep.outputs.iter().enumerate().all(|(b, out)| {
                                let x =
                                    crate::sim::platform::microbatch_input(&graph, total, b);
                                let want = graph.forward_ref(&x, total);
                                out.len() == want.len()
                                    && out.iter().zip(&want).all(|(g, w)| (g - w).abs() < 1e-2)
                            });
                            done(JobResult {
                                cycles: rep.total_cycles,
                                instructions: rep.total_instructions,
                                ipc: if rep.total_cycles > 0 {
                                    rep.total_instructions as f64 / rep.total_cycles as f64
                                } else {
                                    0.0
                                },
                                utilization: rep.utilization,
                                numerics_ok: Some(ok),
                                prefill_cycles: Some(srep.prefill_cycles),
                                cycles_per_token: srep.cycles_per_token(),
                                ..base
                            })
                        }
                        Err(e) => done(JobResult::err(spec, e.to_string(), 0)),
                    };
                }
                return match crate::sim::platform::run_platform_traced(
                    &machines,
                    &graph,
                    &plan,
                    batch,
                    &desc,
                    mode,
                    threads,
                    spec.max_cycles,
                    ptrace.as_mut(),
                ) {
                    Ok(rep) => {
                        if let Some(c) = cap.as_deref_mut() {
                            c.platform_trace = ptrace;
                        }
                        if rep.total_cycles > spec.max_cycles {
                            return done(JobResult::err(
                                spec,
                                format!(
                                    "platform makespan {} exceeds the {}-cycle budget",
                                    rep.total_cycles, spec.max_cycles
                                ),
                                0,
                            ));
                        }
                        let ok = rep.outputs.iter().enumerate().all(|(b, out)| {
                            let x = crate::sim::platform::microbatch_input(&graph, batch, b);
                            let want = graph.forward_ref(&x, batch);
                            out.iter().zip(&want).all(|(g, w)| (g - w).abs() < 1e-2)
                        });
                        done(JobResult {
                            cycles: rep.total_cycles,
                            instructions: rep.total_instructions,
                            ipc: if rep.total_cycles > 0 {
                                rep.total_instructions as f64 / rep.total_cycles as f64
                            } else {
                                0.0
                            },
                            utilization: rep.utilization,
                            numerics_ok: Some(ok),
                            ..base
                        })
                    }
                    Err(e) => done(JobResult::err(spec, e.to_string(), 0)),
                };
            }
            if decode_steps > 0 {
                // Single-chip serving: one persistent step context carries
                // the K/V caches from prefill through every decode step.
                let sched = match lowering::lower_serving(machine, &graph, batch, decode_steps) {
                    Ok(s) => s,
                    Err(e) => return done(JobResult::err(spec, e.to_string(), 0)),
                };
                let total = batch + decode_steps;
                let full = graph.input_batch(total);
                let (prompt, dec) =
                    lowering::split_serving_input(&full, graph.input_features, batch);
                let mut sc = (cap.is_some() && matches!(mode, SimMode::Timed(_)))
                    .then(ScheduleCapture::default);
                return match lowering::run_serving_captured(
                    machine,
                    &sched,
                    &prompt,
                    &dec,
                    mode,
                    spec.max_cycles,
                    sc.as_mut(),
                ) {
                    Ok(rep) => {
                        if let (Some(c), Some(s)) = (cap.as_deref_mut(), sc) {
                            c.stats = Some(s.stats);
                            if c.want_trace {
                                c.trace = Some(s.trace);
                            }
                        }
                        let want = graph.forward_ref(&full, total);
                        let got = rep.assembled_output();
                        let ok = got.len() == want.len()
                            && got.iter().zip(&want).all(|(g, w)| (g - w).abs() < 1e-2);
                        done(JobResult {
                            cycles: rep.total_cycles,
                            instructions: rep.total_instructions,
                            ipc: if rep.total_cycles > 0 {
                                rep.total_instructions as f64 / rep.total_cycles as f64
                            } else {
                                0.0
                            },
                            numerics_ok: Some(ok),
                            prefill_cycles: Some(rep.prefill.total_cycles),
                            cycles_per_token: rep.cycles_per_token(),
                            ..base
                        })
                    }
                    Err(e) => done(JobResult::err(spec, e.to_string(), 0)),
                };
            }
            let lg = match lowering::lower_graph(machine, &graph, batch) {
                Ok(l) => l,
                Err(e) => return done(JobResult::err(spec, e.to_string(), 0)),
            };
            let x = graph.input_batch(batch);
            let mut sc = (cap.is_some() && matches!(mode, SimMode::Timed(_)))
                .then(ScheduleCapture::default);
            match lowering::run_schedule_captured(machine, &lg, &x, mode, spec.max_cycles, sc.as_mut())
            {
                Ok(rep) => {
                    if let (Some(c), Some(s)) = (cap.as_deref_mut(), sc) {
                        c.stats = Some(s.stats);
                        if c.want_trace {
                            c.trace = Some(s.trace);
                        }
                    }
                    let want = graph.forward_ref(&x, batch);
                    let ok = rep
                        .output
                        .iter()
                        .zip(&want)
                        .all(|(g, w)| (g - w).abs() < 1e-2);
                    done(JobResult {
                        cycles: rep.total_cycles,
                        instructions: rep.total_instructions,
                        ipc: if rep.total_cycles > 0 {
                            rep.total_instructions as f64 / rep.total_cycles as f64
                        } else {
                            0.0
                        },
                        numerics_ok: Some(ok),
                        ..base
                    })
                }
                Err(e) => done(JobResult::err(spec, e.to_string(), 0)),
            }
        }
    }
}

/// Fetch the machine from the process-wide cache and execute (standalone
/// path; the pool calls [`execute_on`] with the shared machine directly).
pub fn execute(spec: &JobSpec) -> JobResult {
    execute_captured(spec, None)
}

/// [`execute`] with an optional [`RunCapture`] (see [`execute_on_captured`]).
pub fn execute_captured(spec: &JobSpec, cap: Option<&mut RunCapture>) -> JobResult {
    let start = std::time::Instant::now();
    match super::machines::build_cached(&spec.target) {
        Ok(machine) => execute_on_captured(&machine, spec, cap),
        Err(e) => JobResult::err(spec, e.to_string(), start.elapsed().as_micros() as u64),
    }
}

// ------------------------------------------------------- JSON wire format

impl TargetSpec {
    pub fn to_json(&self) -> Json {
        match self {
            TargetSpec::Oma { cache, mac_latency } => Json::obj(vec![
                ("kind", Json::str("oma")),
                ("cache", Json::Bool(*cache)),
                (
                    "mac_latency",
                    mac_latency.map(|v| Json::num(v as f64)).unwrap_or(Json::Null),
                ),
            ]),
            TargetSpec::Systolic { rows, cols } => Json::obj(vec![
                ("kind", Json::str("systolic")),
                ("rows", Json::num(*rows as f64)),
                ("cols", Json::num(*cols as f64)),
            ]),
            TargetSpec::Gamma { units } => Json::obj(vec![
                ("kind", Json::str("gamma")),
                ("units", Json::num(*units as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        match v.field("kind")?.as_str()? {
            "oma" => Ok(TargetSpec::Oma {
                cache: v.opt_bool("cache", true),
                mac_latency: v
                    .get("mac_latency")
                    .and_then(|x| x.as_u64().ok()),
            }),
            "systolic" => Ok(TargetSpec::Systolic {
                rows: v.field("rows")?.as_usize()?,
                cols: v.field("cols")?.as_usize()?,
            }),
            "gamma" => Ok(TargetSpec::Gamma {
                units: v.field("units")?.as_usize()?,
            }),
            // Inline ADL: `{"kind":"adl","source":"arch … targets … …"}`.
            // The description is elaborated at the wire boundary and
            // resolves to its `targets` binding, so everything downstream
            // (memo keys, machine cache, result rows) sees a plain
            // target spec.  The machine is built through the config-hash
            // cache and cross-checked against the description's graph, so
            // a served job's cycles always come from the architecture the
            // text actually describes.
            "adl" => {
                let src = v.field("source")?.as_str()?;
                // A serving client typically streams many jobs embedding
                // the same description: elaborate + verify once per
                // distinct source (FNV-1a keyed, retention-capped like
                // the machine cache), resolve repeats with a hash lookup.
                static VERIFIED: std::sync::OnceLock<
                    std::sync::Mutex<std::collections::HashMap<u64, TargetSpec>>,
                > = std::sync::OnceLock::new();
                const MAX_VERIFIED_SOURCES: usize = 64;
                let cache = VERIFIED
                    .get_or_init(|| std::sync::Mutex::new(std::collections::HashMap::new()));
                let key = crate::util::hash::fnv1a_str(src);
                if let Some(spec) = super::lock_unpoisoned(cache).get(&key) {
                    return Ok(spec.clone());
                }
                let e = crate::adl::load_str(src)
                    .map_err(|err| JsonError::Invalid(format!("inline ADL: {err}")))?;
                let spec = e.target.clone().ok_or_else(|| {
                    JsonError::Invalid(
                        "inline ADL has no `targets` binding (cannot pick a code generator)"
                            .into(),
                    )
                })?;
                let machine = super::machines::build_cached(&spec)
                    .map_err(|err| JsonError::Invalid(format!("inline ADL: {err}")))?;
                crate::adl::ag_equiv(&e.ag, machine.ag()).map_err(|err| {
                    JsonError::Invalid(format!(
                        "inline ADL does not match its `targets` binding: {err}"
                    ))
                })?;
                let mut map = super::lock_unpoisoned(cache);
                if map.len() < MAX_VERIFIED_SOURCES {
                    map.insert(key, spec.clone());
                }
                drop(map);
                Ok(spec)
            }
            other => Err(JsonError::Invalid(format!(
                "unknown target kind `{other}` (expected oma|systolic|gamma|adl)"
            ))),
        }
    }
}

impl Workload {
    pub fn to_json(&self) -> Json {
        match self {
            Workload::Gemm { m, k, n, tile, order } => Json::obj(vec![
                ("kind", Json::str("gemm")),
                ("m", Json::num(*m as f64)),
                ("k", Json::num(*k as f64)),
                ("n", Json::num(*n as f64)),
                (
                    "tile",
                    tile.map(|t| Json::num(t as f64)).unwrap_or(Json::Null),
                ),
                (
                    "order",
                    order.map(|o| Json::str(o.name())).unwrap_or(Json::Null),
                ),
            ]),
            Workload::Mlp { small, batch } => Json::obj(vec![
                ("kind", Json::str("mlp")),
                ("small", Json::Bool(*small)),
                ("batch", Json::num(*batch as f64)),
            ]),
            // Default-valued fields are elided so the legacy shape's
            // canonical JSON — and therefore its memo key — is unchanged.
            Workload::Transformer { seq, layers, heads, decode_steps } => {
                let mut fields = vec![
                    ("kind", Json::str("transformer")),
                    ("seq", Json::num(*seq as f64)),
                ];
                if *layers != 1 {
                    fields.push(("layers", Json::num(*layers as f64)));
                }
                if *heads != 1 {
                    fields.push(("heads", Json::num(*heads as f64)));
                }
                if *decode_steps != 0 {
                    fields.push(("decode_steps", Json::num(*decode_steps as f64)));
                }
                Json::obj(fields)
            }
        }
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        let wl = match v.field("kind")?.as_str()? {
            "gemm" => Workload::Gemm {
                m: v.field("m")?.as_usize()?,
                k: v.field("k")?.as_usize()?,
                n: v.field("n")?.as_usize()?,
                tile: v.get("tile").and_then(|x| x.as_usize().ok()),
                order: v
                    .get("order")
                    .and_then(|x| x.as_str().ok())
                    .and_then(|name| LoopOrder::ALL.into_iter().find(|o| o.name() == name)),
            },
            "mlp" => Workload::Mlp {
                small: v.opt_bool("small", true),
                batch: v.field("batch")?.as_usize()?,
            },
            "transformer" => Workload::Transformer {
                seq: v.field("seq")?.as_usize()?,
                layers: v.opt_u64("layers", 1) as usize,
                heads: v.opt_u64("heads", 1) as usize,
                decode_steps: v.opt_u64("decode_steps", 0) as usize,
            },
            _ => return Err(JsonError::Type("gemm|mlp|transformer", "other")),
        };
        wl.validate().map_err(JsonError::Invalid)?;
        Ok(wl)
    }
}

impl SimModeSpec {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "functional" => Some(SimModeSpec::Functional),
            "timed" => Some(SimModeSpec::Timed),
            "estimate" => Some(SimModeSpec::Estimate),
            _ => None,
        }
    }
}

/// Per-shape roofline operator sequence, cached by the workload's
/// canonical JSON (FNV-1a keyed).  `lower_bound_cycles` is the DSE
/// pre-filter's hot loop: thousands of candidate targets query the same
/// few workload shapes, and rebuilding a `layers × heads` graph per
/// query is pure waste — the operator sequence depends on the workload
/// alone, never on the target.  Retention-capped like the machine cache;
/// debug builds cross-check every hit against a fresh walk.
fn workload_roofline_ops(wl: &Workload) -> std::sync::Arc<Vec<Operator>> {
    use std::sync::{Arc, Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<std::collections::HashMap<u64, Arc<Vec<Operator>>>>> =
        OnceLock::new();
    const MAX_SHAPES: usize = 256;
    let build = || -> Vec<Operator> {
        match wl {
            Workload::Gemm { .. } => Vec::new(),
            Workload::Mlp { small, batch } => {
                let g = if *small {
                    DnnGraph::mlp_small()
                } else {
                    DnnGraph::mlp_784_256_128_10()
                };
                lowering::roofline_ops(&g, *batch)
            }
            Workload::Transformer { seq, layers: 1, heads: 1, decode_steps: 0 } => {
                lowering::roofline_ops(&DnnGraph::tiny_transformer(), *seq)
            }
            Workload::Transformer { seq, layers, heads, decode_steps } => {
                let g = DnnGraph::transformer(*layers, *heads);
                if *decode_steps == 0 {
                    lowering::roofline_ops(&g, *seq)
                } else {
                    lowering::serving_roofline_ops(&g, *seq, *decode_steps)
                }
            }
        }
    };
    let cache = CACHE.get_or_init(|| Mutex::new(std::collections::HashMap::new()));
    let key = crate::util::hash::fnv1a_str(&wl.to_json().to_string());
    if let Some(ops) = super::lock_unpoisoned(cache).get(&key) {
        debug_assert_eq!(**ops, build(), "stale roofline cache for {}", wl.describe());
        return ops.clone();
    }
    let ops = Arc::new(build());
    let mut map = super::lock_unpoisoned(cache);
    if map.len() < MAX_SHAPES {
        map.insert(key, ops.clone());
    }
    ops
}

impl JobSpec {
    /// Sound lower bound on this job's timed cycles: the target's
    /// roofline summed over the workload's operator sequence
    /// ([`crate::dnn::lowering::roofline_ops`] — GeMM bounds for the
    /// GeMM-backed operators, streaming-traffic bounds for the row-wise
    /// transformer operators).  Target-side padding (Γ̈ rounds dims up to
    /// 8) only raises true cycles, so bounding the unpadded problem stays
    /// sound.  This is the *single* definition the DSE pre-filter
    /// (`dse::lower_bound_cycles`) and the feasibility check below share.
    pub fn lower_bound_cycles(&self) -> u64 {
        let single = self.single_chip_bound_cycles();
        match &self.platform {
            // Busy-time argument: the platform performs `microbatches`
            // full inferences, each lower-bounded by the single-chip
            // bound, spread across at most `chips` chips running
            // concurrently — makespan ≥ ⌈m·base/chips⌉.  Fabric and DRAM
            // costs only add, so this stays sound.
            Some(p) => {
                let m = p.microbatches.max(1) as u64;
                (m * single).div_ceil(p.chips.max(1) as u64)
            }
            None => single,
        }
    }

    fn single_chip_bound_cycles(&self) -> u64 {
        let rl = self.target.roofline();
        match &self.workload {
            Workload::Gemm { m, k, n, .. } => rl.gemm_cycles(&GemmParams::new(*m, *k, *n)),
            wl => workload_roofline_ops(wl)
                .iter()
                .map(|op| rl.op_cycles(op))
                .sum(),
        }
    }

    /// f32 words the workload keeps resident in data memory, including
    /// target-side padding (Γ̈ rounds GeMM dims up to multiples of 8,
    /// exactly as [`execute_on`] does before lowering).  `None` for the
    /// graph workloads, whose schedules stage per-operator tiles rather
    /// than holding the whole operand set.
    pub fn footprint_words(&self) -> Option<u64> {
        match &self.workload {
            Workload::Gemm { m, k, n, .. } => {
                let pad = |d: usize| -> u64 {
                    if matches!(self.target, TargetSpec::Gamma { .. }) {
                        (d.div_ceil(8) * 8) as u64
                    } else {
                        d as u64
                    }
                };
                let (m, k, n) = (pad(*m), pad(*k), pad(*n));
                Some(m * k + k * n + m * n)
            }
            Workload::Mlp { .. } | Workload::Transformer { .. } => None,
        }
    }

    /// Pre-simulation feasibility verdict: `Some(reason)` when this job
    /// provably cannot produce a useful timed result — the operand set
    /// does not fit the target's data memory, or the sound analytical
    /// lower bound already exceeds the cycle budget (so a timed run is
    /// *guaranteed* to hit the limit).
    ///
    /// [`execute_on`] rejects on exactly this predicate before touching
    /// the machine, and the DSE pre-filter prunes on it before a machine
    /// is even built — the two paths agree by construction, which is what
    /// makes pruning infeasible candidates sound (an exhaustive run turns
    /// them into error rows that never join the Pareto frontier).
    pub fn infeasible(&self) -> Option<String> {
        let rl = self.target.roofline();
        if let Some(words) = self.footprint_words() {
            if !rl.fits_capacity(words) {
                return Some(format!(
                    "infeasible: operand footprint {words} words exceeds {} data-memory \
                     capacity ({} words)",
                    self.target.describe(),
                    rl.capacity_words.unwrap_or(0)
                ));
            }
        }
        if self.mode == SimModeSpec::Timed {
            let bound = self.lower_bound_cycles();
            if bound > self.max_cycles {
                return Some(format!(
                    "infeasible: analytical lower bound {bound} cycles exceeds the \
                     {}-cycle budget",
                    self.max_cycles
                ));
            }
        }
        None
    }

    /// Result-row label for the job's target: the plain target, or the
    /// platform wrapper around it (`platform4[systolic_2x2]_h4_m8`).
    pub fn target_label(&self) -> String {
        let t = self.target.describe();
        match &self.platform {
            Some(p) => p.describe(&t),
            None => t,
        }
    }

    /// Area proxy for Pareto plots: a platform replicates the chip.
    pub fn area_proxy(&self) -> f64 {
        let chips = self.platform.map_or(1, |p| p.chips.max(1));
        self.target.area_proxy() * chips as f64
    }

    /// Canonical memo key: FNV-1a over the canonical JSON of the spec's
    /// *semantic identity*.  The id is dropped (it names the request, not
    /// the result), the workload is normalized per target
    /// ([`Workload::canonical_for`]), and the timing backend is dropped —
    /// all backends report identical cycle counts by construction (a
    /// tested invariant), so a result computed on any answers all.  The
    /// platform's thread count is dropped for the same reason; its
    /// chips/fabric/microbatches stay — they change the reported cycles.
    /// `deadline_ms` is dropped too: a wall-clock budget bounds how long
    /// we are willing to *compute* a result, not what the result is, so a
    /// memoized completion answers a request under any budget.
    pub fn canonical_key(&self) -> u64 {
        let mut fields = vec![
            ("target", self.target.to_json()),
            ("workload", self.workload.canonical_for(&self.target).to_json()),
            ("mode", Json::str(self.mode.name())),
            ("max_cycles", Json::num(self.max_cycles as f64)),
        ];
        if let Some(p) = &self.platform {
            fields.push((
                "platform",
                Json::obj(vec![
                    ("chips", Json::num(p.chips as f64)),
                    ("hop_latency", Json::num(p.hop_latency as f64)),
                    ("microbatches", Json::num(p.microbatches as f64)),
                ]),
            ));
        }
        let v = Json::obj(fields);
        crate::util::hash::fnv1a_str(&v.to_string())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("target", self.target.to_json()),
            ("workload", self.workload.to_json()),
            ("mode", Json::str(self.mode.name())),
            ("backend", Json::str(self.backend.name())),
            ("max_cycles", Json::num(self.max_cycles as f64)),
        ];
        if let Some(p) = &self.platform {
            fields.push(("platform", p.to_json()));
        }
        if let Some(ms) = self.deadline_ms {
            fields.push(("deadline_ms", Json::num(ms as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        Ok(JobSpec {
            id: v.field("id")?.as_u64()?,
            target: TargetSpec::from_json(v.field("target")?)?,
            workload: Workload::from_json(v.field("workload")?)?,
            mode: SimModeSpec::from_name(v.field("mode")?.as_str()?)
                .ok_or(JsonError::Type("functional|timed|estimate", "other"))?,
            // Absent/unknown backend defaults to cycle-stepped: old job
            // lines keep working.
            backend: v
                .get("backend")
                .and_then(|x| x.as_str().ok())
                .and_then(BackendKind::from_name)
                .unwrap_or_default(),
            max_cycles: v.opt_u64("max_cycles", default_max_cycles()),
            // Absent = legacy single-chip job.
            platform: match v.get("platform") {
                Some(Json::Null) | None => None,
                Some(p) => Some(PlatformSpec::from_json(p)?),
            },
            // Absent/null = unbounded (legacy behavior).
            deadline_ms: match v.get("deadline_ms") {
                Some(Json::Null) | None => None,
                Some(x) => Some(x.as_u64()?),
            },
        })
    }

    pub fn parse(line: &str) -> Result<Self, JsonError> {
        Self::from_json(&Json::parse(line)?)
    }
}

impl JobResult {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("target", Json::str(self.target.clone())),
            ("workload", Json::str(self.workload.clone())),
            ("mode", Json::str(self.mode.name())),
            ("cycles", Json::num(self.cycles as f64)),
            ("instructions", Json::num(self.instructions as f64)),
            ("ipc", Json::num(self.ipc)),
            ("utilization", Json::num(self.utilization)),
            (
                "numerics_ok",
                self.numerics_ok.map(Json::Bool).unwrap_or(Json::Null),
            ),
            ("wall_micros", Json::num(self.wall_micros as f64)),
            (
                "error",
                self.error
                    .clone()
                    .map(Json::Str)
                    .unwrap_or(Json::Null),
            ),
            ("area_proxy", Json::num(self.area_proxy)),
        ];
        // Serving-phase metrics exist only when the job decoded tokens;
        // absent fields keep legacy result lines byte-stable.
        if let Some(p) = self.prefill_cycles {
            fields.push(("prefill_cycles", Json::num(p as f64)));
        }
        if let Some(c) = self.cycles_per_token {
            fields.push(("cycles_per_token", Json::num(c)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Self, JsonError> {
        Ok(JobResult {
            id: v.field("id")?.as_u64()?,
            target: v.field("target")?.as_str()?.to_string(),
            workload: v.field("workload")?.as_str()?.to_string(),
            mode: SimModeSpec::from_name(v.field("mode")?.as_str()?)
                .ok_or(JsonError::Type("mode", "other"))?,
            cycles: v.field("cycles")?.as_u64()?,
            instructions: v.field("instructions")?.as_u64()?,
            ipc: v.field("ipc")?.as_f64()?,
            utilization: v.field("utilization")?.as_f64()?,
            numerics_ok: v.get("numerics_ok").and_then(|x| x.as_bool().ok()),
            wall_micros: v.opt_u64("wall_micros", 0),
            error: v
                .get("error")
                .and_then(|x| x.as_str().ok())
                .map(|s| s.to_string()),
            area_proxy: v
                .get("area_proxy")
                .and_then(|x| x.as_f64().ok())
                .unwrap_or(0.0),
            prefill_cycles: v.get("prefill_cycles").and_then(|x| x.as_u64().ok()),
            cycles_per_token: v.get("cycles_per_token").and_then(|x| x.as_f64().ok()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_job_roundtrips_json() {
        let spec = JobSpec {
            id: 7,
            target: TargetSpec::Systolic { rows: 4, cols: 4 },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: Some(4),
                order: Some(LoopOrder::Kij),
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 1_000_000,
            platform: None,
            deadline_ms: None,
        };
        let line = spec.to_json().to_string();
        let back = JobSpec::parse(&line).unwrap();
        assert_eq!(back, spec);

        // A job line without a backend field defaults to cycle-stepped.
        let legacy = JobSpec::parse(
            &JobSpec {
                backend: BackendKind::CycleStepped,
                ..spec.clone()
            }
            .to_json()
            .to_string()
            .replace("\"backend\":\"cycle\",", ""),
        )
        .unwrap();
        assert_eq!(legacy.backend, BackendKind::CycleStepped);

        // Results round-trip too.
        let r = execute(&JobSpec {
            max_cycles: 10_000_000,
            target: TargetSpec::Gamma { units: 1 },
            ..spec
        });
        let back = JobResult::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.id, r.id);
        assert_eq!(back.cycles, r.cycles);
        assert_eq!(back.numerics_ok, r.numerics_ok);
    }

    #[test]
    fn canonical_key_collapses_equivalent_specs() {
        let base = JobSpec {
            id: 1,
            target: TargetSpec::Systolic { rows: 4, cols: 4 },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::CycleStepped,
            max_cycles: 1_000_000,
            platform: None,
            deadline_ms: None,
        };
        // Different id / backend / (target-irrelevant) tile+order: same key.
        let same = JobSpec {
            id: 99,
            backend: BackendKind::EventDriven,
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: Some(4),
                order: Some(LoopOrder::Kij),
            },
            ..base.clone()
        };
        assert_eq!(base.canonical_key(), same.canonical_key());

        // On the OMA, tile and order DO reach the generator: distinct keys…
        let oma = JobSpec {
            target: TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
            ..base.clone()
        };
        let oma_kij = JobSpec {
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: Some(LoopOrder::Kij),
            },
            ..oma.clone()
        };
        assert_ne!(oma.canonical_key(), oma_kij.canonical_key());
        // …but the default order and a dim-covering tile normalize away.
        let oma_explicit_default = JobSpec {
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: Some(16),
                order: Some(LoopOrder::Ijk),
            },
            ..oma.clone()
        };
        assert_eq!(oma.canonical_key(), oma_explicit_default.canonical_key());

        // Different problem: different key.
        let bigger = JobSpec {
            workload: Workload::Gemm {
                m: 16,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            ..base.clone()
        };
        assert_ne!(base.canonical_key(), bigger.canonical_key());
    }

    #[test]
    fn timed_gemm_job_executes_with_valid_numerics() {
        let spec = JobSpec {
            id: 1,
            target: TargetSpec::Gamma { units: 1 },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::CycleStepped,
            max_cycles: 10_000_000,
            platform: None,
            deadline_ms: None,
        };
        let r = execute(&spec);
        assert_eq!(r.error, None);
        assert!(r.cycles > 0);
        assert_eq!(r.numerics_ok, Some(true));

        // The event-driven backend reports the identical cycle count and
        // numerics on the same job.
        let ev = execute(&JobSpec {
            backend: BackendKind::EventDriven,
            ..spec
        });
        assert_eq!(ev.error, None);
        assert_eq!(ev.cycles, r.cycles, "backends agree on cycles");
        assert_eq!(ev.instructions, r.instructions);
        assert_eq!(ev.numerics_ok, Some(true));
    }

    #[test]
    fn captured_run_matches_plain_run_and_reconciles() {
        let spec = JobSpec {
            id: 2,
            target: TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 10_000_000,
            platform: None,
            deadline_ms: None,
        };
        let plain = execute(&spec);
        assert_eq!(plain.error, None);
        let mut cap = RunCapture {
            want_trace: true,
            ..RunCapture::default()
        };
        let r = execute_captured(&spec, Some(&mut cap));
        assert_eq!(r.error, None);
        assert_eq!(r.cycles, plain.cycles, "tracing must not change timing");
        let st = cap.stats.expect("stats captured");
        assert_eq!(st.cycles, r.cycles);
        let tr = cap.trace.expect("trace captured");
        assert_eq!(tr.cycles, r.cycles);
        // Span sums reconcile with the engine's busy counters, and the
        // stats JSON carries the same totals the trace decomposes.
        let busy = tr.fu_busy_totals();
        assert_eq!(busy.len(), st.fu_busy.len());
        for (i, (name, total)) in st.fu_busy.iter().enumerate() {
            assert_eq!(busy[i], *total, "FU span sum == busy_cycles ({name})");
        }
        let js = st.to_json().to_string();
        assert!(js.contains("\"schema\":\"acadl.simstats/1\""), "{js}");

        // A layered schedule captures merged stats + a concatenated trace.
        let mlp = JobSpec {
            workload: Workload::Mlp {
                small: true,
                batch: 2,
            },
            ..spec.clone()
        };
        let mut mcap = RunCapture {
            want_trace: true,
            ..RunCapture::default()
        };
        let mr = execute_captured(&mlp, Some(&mut mcap));
        assert_eq!(mr.error, None);
        let mst = mcap.stats.expect("schedule stats");
        assert_eq!(mst.cycles, mr.cycles, "merged stats cover the schedule");
        let mtr = mcap.trace.expect("schedule trace");
        assert_eq!(mtr.cycles, mr.cycles);
        let mbusy = mtr.fu_busy_totals();
        for (i, (name, total)) in mst.fu_busy.iter().enumerate() {
            assert_eq!(mbusy[i], *total, "schedule span sum == busy ({name})");
        }

        // A platform job yields the platform-level trace instead.
        let plat = JobSpec {
            platform: Some(PlatformSpec {
                chips: 2,
                hop_latency: 4,
                microbatches: 3,
                threads: 1,
            }),
            ..mlp
        };
        let mut pcap = RunCapture {
            want_trace: true,
            ..RunCapture::default()
        };
        let pr = execute_captured(&plat, Some(&mut pcap));
        assert_eq!(pr.error, None, "{pr:?}");
        let pt = pcap.platform_trace.expect("platform trace");
        assert_eq!(pt.total_cycles, pr.cycles);
        assert_eq!(pt.chips.len(), 2);
        assert!(pcap.trace.is_none(), "platform jobs trace at platform level");
    }

    #[test]
    fn transformer_job_roundtrips_and_executes() {
        let spec = JobSpec {
            id: 11,
            target: TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
            workload: Workload::Transformer { seq: 8, layers: 1, heads: 1, decode_steps: 0 },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 500_000_000,
            platform: None,
            deadline_ms: None,
        };
        let back = JobSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(spec.workload.describe(), "tiny_transformer_s8");

        let r = execute(&spec);
        assert_eq!(r.error, None);
        assert!(r.cycles > 0);
        assert_eq!(r.numerics_ok, Some(true));
        // Backend aliases share a canonical key (the memo collapses them).
        let cs = JobSpec {
            backend: BackendKind::CycleStepped,
            ..spec.clone()
        };
        assert_eq!(spec.canonical_key(), cs.canonical_key());
        assert_ne!(
            spec.canonical_key(),
            JobSpec {
                workload: Workload::Transformer { seq: 16, layers: 1, heads: 1, decode_steps: 0 },
                ..spec.clone()
            }
            .canonical_key()
        );
        // New axes are part of the identity too.
        assert_ne!(
            spec.canonical_key(),
            JobSpec {
                workload: Workload::Transformer { seq: 8, layers: 2, heads: 2, decode_steps: 0 },
                ..spec
            }
            .canonical_key()
        );
    }

    #[test]
    fn legacy_transformer_wire_shape_keeps_memo_identity() {
        // `{"kind":"transformer","seq":N}` — the PR-5 wire shape — must
        // still parse, map, and hit the same memo entries as before.
        let line = r#"{"id":1,"target":{"kind":"oma"},"workload":{"kind":"transformer","seq":8},"mode":"timed"}"#;
        let spec = JobSpec::parse(line).unwrap();
        assert_eq!(
            spec.workload,
            Workload::Transformer { seq: 8, layers: 1, heads: 1, decode_steps: 0 }
        );
        assert_eq!(spec.workload.describe(), "tiny_transformer_s8");
        // Default axes are elided on re-encode, so the canonical JSON —
        // and the FNV memo key derived from it — is byte-identical to
        // what PR 5 hashed.
        let j = spec.workload.to_json().to_string();
        assert!(!j.contains("layers") && !j.contains("heads") && !j.contains("decode"), "{j}");
        let roundtrip = JobSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(spec.canonical_key(), roundtrip.canonical_key());
    }

    #[test]
    fn degenerate_dimensions_are_rejected_at_the_wire() {
        let mk = |wl: &str| {
            format!(r#"{{"id":1,"target":{{"kind":"oma"}},"workload":{wl},"mode":"functional"}}"#)
        };
        for wl in [
            r#"{"kind":"transformer","seq":0}"#,
            r#"{"kind":"transformer","seq":8,"layers":0}"#,
            r#"{"kind":"transformer","seq":8,"layers":999}"#,
            r#"{"kind":"transformer","seq":8,"heads":3}"#,
            r#"{"kind":"transformer","seq":8,"decode_steps":9999}"#,
            r#"{"kind":"transformer","seq":2048}"#,
            r#"{"kind":"mlp","batch":0}"#,
        ] {
            let err = JobSpec::parse(&mk(wl)).unwrap_err();
            assert!(matches!(err, JsonError::Invalid(_)), "{wl}: {err}");
        }
        // Platform microbatch bounds too: 0 would silently clamp, huge
        // values would pipeline an effectively unbounded session loop.
        for mb in ["0", "100000"] {
            let line = format!(
                r#"{{"id":1,"target":{{"kind":"oma"}},"workload":{{"kind":"mlp","batch":2}},"mode":"timed","platform":{{"chips":2,"microbatches":{mb}}}}}"#
            );
            let err = JobSpec::parse(&line).unwrap_err();
            assert!(err.to_string().contains("microbatches"), "{err}");
        }
        // CLI-side validation shares the same predicate.
        assert!(Workload::Transformer { seq: 4, layers: 2, heads: 5, decode_steps: 0 }
            .validate()
            .is_err());
        assert!(Workload::Transformer { seq: 4, layers: 2, heads: 4, decode_steps: 8 }
            .validate()
            .is_ok());
    }

    #[test]
    fn serving_transformer_job_executes_with_phase_metrics() {
        let spec = JobSpec {
            id: 31,
            target: TargetSpec::Oma { cache: true, mac_latency: None },
            workload: Workload::Transformer { seq: 4, layers: 2, heads: 2, decode_steps: 3 },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 500_000_000,
            platform: None,
            deadline_ms: None,
        };
        assert_eq!(spec.workload.describe(), "transformer_s4_l2_h2_d3");
        let back = JobSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);

        let r = execute(&spec);
        assert_eq!(r.error, None, "{r:?}");
        assert!(r.cycles > 0);
        assert_eq!(r.numerics_ok, Some(true));
        let pf = r.prefill_cycles.expect("serving jobs report prefill cycles");
        assert!(pf > 0 && pf < r.cycles, "prefill {pf} vs total {}", r.cycles);
        assert!(r.cycles_per_token.expect("serving jobs report cyc/tok") > 0.0);
        // Phase metrics survive the wire.
        let rb = JobResult::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(rb.prefill_cycles, r.prefill_cycles);
        let (a, b) = (rb.cycles_per_token.unwrap(), r.cycles_per_token.unwrap());
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        // Legacy jobs keep None (and elide the fields entirely).
        let legacy = execute(&JobSpec {
            workload: Workload::Transformer { seq: 4, layers: 1, heads: 1, decode_steps: 0 },
            ..spec.clone()
        });
        assert_eq!(legacy.prefill_cycles, None);
        assert!(!legacy.to_json().to_string().contains("prefill_cycles"));

        // The same serving job shards across a 2-chip platform, with
        // thread-invariant cycles.
        let plat = |threads| {
            execute(&JobSpec {
                platform: Some(PlatformSpec {
                    chips: 2,
                    hop_latency: 4,
                    microbatches: 2,
                    threads,
                }),
                ..spec.clone()
            })
        };
        let p1 = plat(1);
        let p4 = plat(4);
        assert_eq!(p1.error, None, "{p1:?}");
        assert_eq!(p1.numerics_ok, Some(true));
        assert!(p1.prefill_cycles.unwrap() > 0);
        assert!(p1.cycles_per_token.unwrap() > 0.0);
        assert_eq!(p1.cycles, p4.cycles);
        assert_eq!(p1.prefill_cycles, p4.prefill_cycles);
    }

    #[test]
    fn roofline_bound_is_cached_and_stays_sound_for_serving() {
        let mk = |decode_steps| JobSpec {
            id: 0,
            target: TargetSpec::Systolic { rows: 2, cols: 2 },
            workload: Workload::Transformer { seq: 4, layers: 2, heads: 2, decode_steps },
            mode: SimModeSpec::Timed,
            backend: BackendKind::default(),
            max_cycles: 500_000_000,
            platform: None,
            deadline_ms: None,
        };
        let b0 = mk(2).lower_bound_cycles();
        assert!(b0 > 0);
        // Repeat queries hit the cache (debug builds cross-check the
        // cached ops against a fresh graph walk) and stay identical.
        assert_eq!(mk(2).lower_bound_cycles(), b0);
        // More decode steps only add operators, so the bound grows.
        assert!(mk(4).lower_bound_cycles() > b0);
        // And the bound stays below the simulated cycles (soundness).
        let r = execute(&mk(2));
        assert_eq!(r.error, None, "{r:?}");
        assert!(r.cycles >= b0, "bound {b0} vs cycles {}", r.cycles);
    }

    #[test]
    fn platform_job_roundtrips_and_executes() {
        let spec = JobSpec {
            id: 21,
            target: TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
            workload: Workload::Mlp {
                small: true,
                batch: 4,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::ParallelEvent,
            max_cycles: 500_000_000,
            platform: Some(PlatformSpec {
                chips: 2,
                hop_latency: 4,
                microbatches: 3,
                threads: 2,
            }),
            deadline_ms: None,
        };
        let back = JobSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(spec.target_label(), "platform2[oma+cache]_h4_m3");
        assert_eq!(spec.area_proxy(), 2.0 * spec.target.area_proxy());

        let r = execute(&spec);
        assert_eq!(r.error, None, "{r:?}");
        assert!(r.cycles > 0);
        assert_eq!(r.numerics_ok, Some(true));
        assert!(r.cycles >= spec.lower_bound_cycles());

        // Thread count never changes the reported cycles — or the memo key.
        let serial = execute(&JobSpec {
            platform: Some(PlatformSpec {
                threads: 1,
                ..spec.platform.unwrap()
            }),
            ..spec.clone()
        });
        assert_eq!(serial.cycles, r.cycles);
        assert_eq!(serial.instructions, r.instructions);
        assert_eq!(
            spec.canonical_key(),
            JobSpec {
                platform: Some(PlatformSpec {
                    threads: 8,
                    ..spec.platform.unwrap()
                }),
                ..spec.clone()
            }
            .canonical_key()
        );
        // …but the platform shape is part of the identity.
        assert_ne!(
            spec.canonical_key(),
            JobSpec {
                platform: None,
                deadline_ms: None,
                ..spec.clone()
            }
            .canonical_key()
        );

        // A GeMM has no layer boundaries to shard across chips.
        let bad = execute(&JobSpec {
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            ..spec
        });
        assert!(bad.error.unwrap().contains("layered workload"));
    }

    #[test]
    fn estimate_mode_is_faster_than_timed() {
        let mk = |mode| JobSpec {
            id: 0,
            target: TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode,
            backend: BackendKind::default(),
            max_cycles: 50_000_000,
            platform: None,
            deadline_ms: None,
        };
        let timed = execute(&mk(SimModeSpec::Timed));
        let est = execute(&mk(SimModeSpec::Estimate));
        assert_eq!(timed.error, None);
        assert_eq!(est.error, None);
        assert!(est.cycles > 0);
        assert!(
            est.wall_micros < timed.wall_micros,
            "estimate {}µs vs timed {}µs",
            est.wall_micros,
            timed.wall_micros
        );
    }

    #[test]
    fn inline_adl_target_resolves_and_executes() {
        let src = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../examples/gamma_1u.acadl"
        ))
        .expect("example file");
        let line = Json::obj(vec![
            ("id", Json::num(5)),
            (
                "target",
                Json::obj(vec![("kind", Json::str("adl")), ("source", Json::str(src))]),
            ),
            (
                "workload",
                Json::obj(vec![
                    ("kind", Json::str("gemm")),
                    ("m", Json::num(8)),
                    ("k", Json::num(8)),
                    ("n", Json::num(8)),
                ]),
            ),
            ("mode", Json::str("timed")),
            ("max_cycles", Json::num(10_000_000)),
        ])
        .to_string();
        let spec = JobSpec::parse(&line).unwrap();
        assert_eq!(spec.target, TargetSpec::Gamma { units: 1 });
        let r = execute(&spec);
        assert_eq!(r.error, None);
        assert_eq!(r.numerics_ok, Some(true));
        // Same cycles as the explicit spec — it *is* the same machine.
        let explicit = execute(&JobSpec {
            target: TargetSpec::Gamma { units: 1 },
            ..spec.clone()
        });
        assert_eq!(r.cycles, explicit.cycles);

        // Inline ADL without a `targets` binding is rejected up front.
        // Strip the binding AND the param axis (params alone would fail
        // earlier, in elaboration), so this exercises the dedicated
        // no-binding arm of the wire decoder.
        let bad = line
            .replace("targets gamma {\\n  units = 1\\n}\\n", "\\n")
            .replace("param units in [1, 2, 4]\\n", "");
        let err = JobSpec::parse(&bad).unwrap_err();
        assert!(err.to_string().contains("no `targets` binding"), "{err}");
    }

    #[test]
    fn bad_target_reports_error() {
        let spec = JobSpec {
            id: 9,
            target: TargetSpec::Oma {
                cache: false,
                mac_latency: None,
            },
            workload: Workload::Gemm {
                m: 4,
                k: 4,
                n: 4,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::default(),
            max_cycles: 10, // guaranteed cycle-limit error
            platform: None,
            deadline_ms: None,
        };
        let r = execute(&spec);
        assert!(r.error.is_some());
    }
}
