//! The worker pool: a hand-rolled thread pool (the offline build has no
//! async runtime — DESIGN.md §Substitutions) executing job batches,
//! **grouped by target** so each architecture graph builds once and is
//! shared (`Arc`) across that target's jobs — the coordinator's batching
//! policy.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::mapping::uma::Machine;

use super::job::{JobResult, JobSpec};
use super::lock_unpoisoned;
use super::supervisor;

/// Group specs by serialized target (machines are reused within a group).
fn group_by_target(specs: &[JobSpec]) -> Vec<Vec<JobSpec>> {
    let mut groups: HashMap<String, Vec<JobSpec>> = HashMap::new();
    for s in specs {
        groups
            .entry(s.target.to_json().to_string())
            .or_default()
            .push(s.clone());
    }
    groups.into_values().collect()
}

/// Run all jobs with at most `workers` concurrent evaluations; results are
/// returned sorted by job id.  Work is distributed over a shared channel
/// so long jobs don't starve short ones (work stealing by contention).
pub fn run_jobs(specs: Vec<JobSpec>, workers: usize) -> Vec<JobResult> {
    let n = specs.len();
    if n == 0 {
        return Vec::new();
    }
    // Lease worker threads from the process-wide `--jobs` budget so a DSE
    // sweep running platform jobs (which lease their own simulation
    // threads) cannot oversubscribe the host.
    let lease = crate::util::jobs::lease(workers);
    let workers = lease.granted;
    // Fetch each target's machine from the process-wide cache (built at
    // most once per distinct config, shared across batches and workers).
    type Work = (Option<Arc<Machine>>, JobSpec);
    let (work_tx, work_rx) = mpsc::channel::<Work>();
    'groups: for group in group_by_target(&specs) {
        let machine = super::machines::build_cached(&group[0].target).ok();
        for spec in group {
            if work_tx.send((machine.clone(), spec)).is_err() {
                // Receiver gone (cannot normally happen: we hold it below);
                // stop enqueuing entirely rather than panicking the caller
                // or building machines for further doomed groups.
                break 'groups;
            }
        }
    }
    drop(work_tx);

    let work_rx = Arc::new(Mutex::new(work_rx));
    let (res_tx, res_rx) = mpsc::channel::<JobResult>();
    // Worker threads do not inherit the caller's thread-local cancel
    // token; capture it here so a deadline or disconnect observed by the
    // caller (e.g. the DSE wave loop under a server job) also stops the
    // jobs this pool fans out.
    let caller_token = crate::util::cancel::current();
    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            let work_rx = Arc::clone(&work_rx);
            let res_tx = res_tx.clone();
            let token = caller_token.clone();
            scope.spawn(move || {
                let _token_guard = token.map(crate::util::cancel::install);
                loop {
                    let item = { lock_unpoisoned(&work_rx).recv() };
                    match item {
                        Ok((machine, spec)) => {
                            // Supervised: a panicking job becomes an error
                            // row instead of killing the worker (and with
                            // it the whole scope).
                            let result = match &machine {
                                Some(m) => supervisor::execute_on(m, &spec),
                                // Re-report the machine build error.
                                None => supervisor::execute(&spec),
                            };
                            if res_tx.send(result).is_err() {
                                return;
                            }
                        }
                        Err(_) => return, // queue drained
                    }
                }
            });
        }
        drop(res_tx);
        let mut results: Vec<JobResult> = res_rx.iter().collect();
        results.sort_by_key(|r| r.id);
        results
    })
}

/// Alias kept for API symmetry with the async-runtime version this
/// replaces (benches and the CLI call this name).
pub fn run_jobs_blocking(specs: Vec<JobSpec>, workers: usize) -> Vec<JobResult> {
    run_jobs(specs, workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{SimModeSpec, TargetSpec, Workload};

    fn gemm_spec(id: u64, rows: usize) -> JobSpec {
        JobSpec {
            id,
            target: TargetSpec::Systolic { rows, cols: rows },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: Default::default(),
            max_cycles: 10_000_000,
            platform: None,
            deadline_ms: None,
        }
    }

    #[test]
    fn pool_runs_batch_and_orders_results() {
        let specs: Vec<JobSpec> = (0..6)
            .map(|i| gemm_spec(i, 2 + (i as usize % 2) * 2))
            .collect();
        let results = run_jobs(specs, 4);
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.error, None, "{r:?}");
            assert!(r.cycles > 0);
        }
        // Same target → identical deterministic cycles (machine reuse must
        // not leak state between jobs).
        assert_eq!(results[0].cycles, results[2].cycles);
        assert_eq!(results[1].cycles, results[3].cycles);
    }

    #[test]
    fn pool_survives_failing_jobs() {
        let mut specs = vec![gemm_spec(0, 2)];
        specs.push(JobSpec {
            max_cycles: 5,
            ..gemm_spec(1, 2)
        });
        let results = run_jobs(specs, 2);
        assert_eq!(results[0].error, None);
        assert!(results[1].error.is_some());
    }

    #[test]
    fn pool_contains_panicking_jobs() {
        // Opt this process into fault injection (and leave it on — only
        // ids carrying a chaos mark trip it, so concurrently running
        // tests with plain small ids are unaffected).
        std::env::set_var("ACADL_CHAOS", "1");
        let poisoned = crate::coordinator::job::CHAOS_PANIC_MARK | 7;
        let specs = vec![gemm_spec(0, 2), gemm_spec(poisoned, 2), gemm_spec(1, 2)];
        let results = run_jobs(specs, 2);
        assert_eq!(results.len(), 3, "panic must not swallow the batch");
        assert_eq!(results[0].error, None);
        assert_eq!(results[1].error, None);
        assert_eq!(
            results[2].error_class(),
            Some(crate::coordinator::job::JobError::Panic),
            "{:?}",
            results[2].error
        );
        // The healthy jobs around the panic report real cycles.
        assert_eq!(results[0].cycles, results[1].cycles);
    }

    #[test]
    fn machine_cache_reused_across_batches() {
        // Two separate run_jobs calls on the same exotic target: the
        // second batch must not rebuild the architecture graph.
        let mk = |id| JobSpec {
            target: TargetSpec::Systolic { rows: 5, cols: 3 },
            ..gemm_spec(id, 2)
        };
        let _ = run_jobs(vec![mk(0)], 1);
        let (hits_before, misses_before) = crate::coordinator::machines::cache_stats();
        let results = run_jobs(vec![mk(1), mk(2)], 2);
        let (hits_after, misses_after) = crate::coordinator::machines::cache_stats();
        assert!(results.iter().all(|r| r.error.is_none()));
        assert!(hits_after > hits_before, "second batch reuses the machine");
        // Other tests may add misses concurrently for their own targets,
        // but this exact config was already cached: misses can only have
        // grown for *other* configs.  Sanity: at least no runaway rebuild.
        assert!(misses_after >= misses_before);
    }

    #[test]
    fn single_worker_matches_parallel() {
        let specs: Vec<JobSpec> = (0..4).map(|i| gemm_spec(i, 2)).collect();
        let serial = run_jobs(specs.clone(), 1);
        let parallel = run_jobs(specs, 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.cycles, b.cycles, "determinism across worker counts");
        }
    }
}
