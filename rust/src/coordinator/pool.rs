//! The worker pool: a hand-rolled thread pool (the offline build has no
//! async runtime — DESIGN.md §Substitutions) executing job batches,
//! **grouped by target** so each architecture graph builds once and is
//! shared (`Arc`) across that target's jobs — the coordinator's batching
//! policy.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::mapping::uma::Machine;

use super::job::{execute_on, JobResult, JobSpec};

/// Lock with poison recovery: a worker that panicked mid-job poisons the
/// mutex, but the queue state it guards (an mpsc receiver) is still
/// coherent — the remaining workers keep draining instead of cascading
/// panics through every `.lock().expect(..)`.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Group specs by serialized target (machines are reused within a group).
fn group_by_target(specs: &[JobSpec]) -> Vec<Vec<JobSpec>> {
    let mut groups: HashMap<String, Vec<JobSpec>> = HashMap::new();
    for s in specs {
        groups
            .entry(s.target.to_json().to_string())
            .or_default()
            .push(s.clone());
    }
    groups.into_values().collect()
}

/// Run all jobs with at most `workers` concurrent evaluations; results are
/// returned sorted by job id.  Work is distributed over a shared channel
/// so long jobs don't starve short ones (work stealing by contention).
pub fn run_jobs(specs: Vec<JobSpec>, workers: usize) -> Vec<JobResult> {
    let n = specs.len();
    if n == 0 {
        return Vec::new();
    }
    // Build each target's machine once.
    type Work = (Option<Arc<Machine>>, JobSpec);
    let (work_tx, work_rx) = mpsc::channel::<Work>();
    'groups: for group in group_by_target(&specs) {
        let machine = group[0].target.to_config().build().ok().map(Arc::new);
        for spec in group {
            if work_tx.send((machine.clone(), spec)).is_err() {
                // Receiver gone (cannot normally happen: we hold it below);
                // stop enqueuing entirely rather than panicking the caller
                // or building machines for further doomed groups.
                break 'groups;
            }
        }
    }
    drop(work_tx);

    let work_rx = Arc::new(Mutex::new(work_rx));
    let (res_tx, res_rx) = mpsc::channel::<JobResult>();
    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            let work_rx = Arc::clone(&work_rx);
            let res_tx = res_tx.clone();
            scope.spawn(move || loop {
                let item = { lock_unpoisoned(&work_rx).recv() };
                match item {
                    Ok((machine, spec)) => {
                        let result = match &machine {
                            Some(m) => execute_on(m, &spec),
                            None => super::job::execute(&spec), // re-report build error
                        };
                        if res_tx.send(result).is_err() {
                            return;
                        }
                    }
                    Err(_) => return, // queue drained
                }
            });
        }
        drop(res_tx);
        let mut results: Vec<JobResult> = res_rx.iter().collect();
        results.sort_by_key(|r| r.id);
        results
    })
}

/// Alias kept for API symmetry with the async-runtime version this
/// replaces (benches and the CLI call this name).
pub fn run_jobs_blocking(specs: Vec<JobSpec>, workers: usize) -> Vec<JobResult> {
    run_jobs(specs, workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{SimModeSpec, TargetSpec, Workload};

    fn gemm_spec(id: u64, rows: usize) -> JobSpec {
        JobSpec {
            id,
            target: TargetSpec::Systolic { rows, cols: rows },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: Default::default(),
            max_cycles: 10_000_000,
        }
    }

    #[test]
    fn pool_runs_batch_and_orders_results() {
        let specs: Vec<JobSpec> = (0..6)
            .map(|i| gemm_spec(i, 2 + (i as usize % 2) * 2))
            .collect();
        let results = run_jobs(specs, 4);
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.error, None, "{r:?}");
            assert!(r.cycles > 0);
        }
        // Same target → identical deterministic cycles (machine reuse must
        // not leak state between jobs).
        assert_eq!(results[0].cycles, results[2].cycles);
        assert_eq!(results[1].cycles, results[3].cycles);
    }

    #[test]
    fn pool_survives_failing_jobs() {
        let mut specs = vec![gemm_spec(0, 2)];
        specs.push(JobSpec {
            max_cycles: 5,
            ..gemm_spec(1, 2)
        });
        let results = run_jobs(specs, 2);
        assert_eq!(results[0].error, None);
        assert!(results[1].error.is_some());
    }

    #[test]
    fn single_worker_matches_parallel() {
        let specs: Vec<JobSpec> = (0..4).map(|i| gemm_spec(i, 2)).collect();
        let serial = run_jobs(specs.clone(), 1);
        let parallel = run_jobs(specs, 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.cycles, b.cycles, "determinism across worker counts");
        }
    }
}
