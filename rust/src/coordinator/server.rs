//! Line-delimited-JSON TCP server: external optimization loops (NAS, DSE
//! scripts) submit [`JobSpec`] lines and receive [`JobResult`] lines.
//!
//! Protocol: one JSON `JobSpec` per line in, one JSON `JobResult` per line
//! out (same order per connection).  Malformed lines produce an error
//! object instead of killing the connection.  Thread-per-connection with a
//! global simulation-slot semaphore (the offline build has no async
//! runtime — DESIGN.md §Substitutions).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::json::Json;

use super::job::{execute, JobSpec};

/// Counting semaphore bounding concurrent simulations across connections.
///
/// Lock poisoning (a handler thread panicking while holding the count)
/// must not take the whole server down: the counter itself is a plain
/// integer that is never left mid-update, so both paths recover the guard
/// from a poisoned mutex instead of panicking every later connection.
pub struct Slots {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Slots {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Slots {
            count: Mutex::new(n.max(1)),
            cv: Condvar::new(),
        })
    }

    fn acquire(&self) {
        let mut c = match self.count.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        while *c == 0 {
            c = match self.cv.wait(c) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        *c -= 1;
    }

    fn release(&self) {
        let mut c = match self.count.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *c += 1;
        drop(c);
        self.cv.notify_one();
    }
}

/// Serve until the listener is closed.  Per-connection accept errors
/// (ECONNABORTED and friends) are transient on a loaded listener and must
/// not kill the serving loop; only the fatal "listener gone" path returns.
pub fn serve(listener: TcpListener, workers: usize) -> std::io::Result<()> {
    // Clamp the slot count to the process-wide `--jobs` budget so a
    // server colocated with sweeps cannot oversubscribe the host.
    let slots = Slots::new(workers.min(crate::util::jobs::configured()).max(1));
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::ConnectionAborted => continue,
            Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => continue,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        let slots = Arc::clone(&slots);
        std::thread::spawn(move || {
            let _ = handle(stream, slots);
        });
    }
    Ok(())
}

/// Releases its slot on drop, so a panicking job cannot leak a
/// simulation slot and slowly starve the server.
struct SlotGuard<'a>(&'a Slots);

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        self.0.release();
    }
}

fn handle(stream: TcpStream, slots: Arc<Slots>) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match JobSpec::parse(&line) {
            Ok(spec) => {
                slots.acquire();
                let _guard = SlotGuard(&slots);
                let result = execute(&spec);
                result.to_json().to_string()
            }
            Err(e) => Json::obj(vec![(
                "error",
                Json::str(format!("bad request: {e}")),
            )])
            .to_string(),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{JobResult, SimModeSpec, TargetSpec, Workload};

    fn start_server(workers: usize) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = serve(listener, workers);
        });
        addr
    }

    #[test]
    fn serves_a_job_over_tcp() {
        let addr = start_server(2);
        let spec = JobSpec {
            id: 42,
            target: TargetSpec::Gamma { units: 1 },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: Default::default(),
            max_cycles: 10_000_000,
            platform: None,
        };
        let mut stream = TcpStream::connect(addr).expect("connect");
        let line = spec.to_json().to_string() + "\n";
        stream.write_all(line.as_bytes()).unwrap();

        let mut reader = BufReader::new(stream);
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let result =
            JobResult::from_json(&Json::parse(reply.trim()).unwrap()).expect("result json");
        assert_eq!(result.id, 42);
        assert_eq!(result.error, None);
        assert!(result.cycles > 0);
        assert_eq!(result.numerics_ok, Some(true));
    }

    #[test]
    fn bad_request_gets_error_line() {
        let addr = start_server(1);
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(b"this is not json\n").unwrap();
        let mut reader = BufReader::new(stream);
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains("bad request"), "{reply}");
    }

    #[test]
    fn multiple_jobs_one_connection_preserve_order() {
        let addr = start_server(2);
        let mut stream = TcpStream::connect(addr).expect("connect");
        for id in 0..3u64 {
            let spec = JobSpec {
                id,
                target: TargetSpec::Systolic { rows: 2, cols: 2 },
                workload: Workload::Gemm {
                    m: 4,
                    k: 4,
                    n: 4,
                    tile: None,
                    order: None,
                },
                mode: SimModeSpec::Estimate,
                backend: Default::default(),
                max_cycles: 10_000_000,
                platform: None,
            };
            let line = spec.to_json().to_string() + "\n";
            stream.write_all(line.as_bytes()).unwrap();
        }
        let mut reader = BufReader::new(stream);
        for id in 0..3u64 {
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            let result = JobResult::from_json(&Json::parse(reply.trim()).unwrap()).unwrap();
            assert_eq!(result.id, id);
        }
    }
}
