//! Line-delimited-JSON TCP server: external optimization loops (NAS, DSE
//! scripts) submit [`JobSpec`] lines and receive [`JobResult`] lines.
//!
//! Protocol: one JSON `JobSpec` per line in, one JSON `JobResult` per line
//! out (same order per connection).  Malformed lines produce an error
//! object instead of killing the connection.  Thread-per-connection with a
//! global simulation-slot semaphore (the offline build has no async
//! runtime — DESIGN.md §Substitutions).
//!
//! Hardening (DESIGN.md §Supervision & fault containment):
//!
//! * **Bounded everything.**  Concurrent connections are capped
//!   ([`ServeCfg::max_connections`]); requests beyond the simulation
//!   slots wait in a bounded admission queue
//!   ([`ServeCfg::queue_depth`]) and are *shed* with an explicit
//!   `overloaded` error line once it fills — the server answers
//!   overload, it never silently hangs clients.
//! * **Bounded time.**  Each job runs under [`supervisor`] with a
//!   per-connection disconnect watch: a client that goes away cancels
//!   its in-flight simulation cooperatively.  `deadline_ms` on the spec
//!   (or [`ServeCfg::default_deadline_ms`]) bounds wall-clock per job.
//!   Idle connections and mid-line stalls (slow-loris writers) are
//!   closed after [`ServeCfg::idle_timeout`].
//! * **Fault containment.**  Job panics become error result lines
//!   (`panic: …`); write errors to a dead client release the slot via
//!   RAII and end the handler quietly.  Graceful shutdown
//!   ([`ServerHandle::shutdown`]) stops accepting, lets in-flight
//!   connections finish, then returns.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::cancel::CancelToken;
use crate::util::json::Json;

use super::job::JobSpec;
use super::lock_unpoisoned;
use super::supervisor;

/// Per-read poll interval: short enough that handlers observe shutdown
/// and enforce idle budgets promptly, long enough to stay negligible.
const READ_POLL: Duration = Duration::from_millis(50);
/// Disconnect-watch poll interval (bounds cancel latency on disconnect).
const WATCH_POLL: Duration = Duration::from_millis(20);
/// Hard cap on one request line (inline ADL sources included).  A line
/// this long is a protocol error or an attack, not a job.
const MAX_LINE_BYTES: usize = 4 << 20;

/// Server tuning knobs.  [`ServeCfg::new`] gives production defaults;
/// tests shrink the timeouts and bounds to exercise the shed paths.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Concurrent simulation slots (clamped to the `--jobs` budget).
    pub workers: usize,
    /// Accept cap: connections beyond this are shed with `overloaded`.
    pub max_connections: usize,
    /// Requests allowed to *wait* for a slot (per server, not per
    /// connection); beyond this the request is shed with `overloaded`.
    pub queue_depth: usize,
    /// Close a connection after this long with no complete request line
    /// (covers both idle keep-alives and slow-loris partial lines).
    /// `None` = never (legacy behavior; shutdown can still drain idle
    /// connections because reads poll).
    pub idle_timeout: Option<Duration>,
    /// Deadline applied to jobs that don't carry their own
    /// `deadline_ms`.  `None` = unbounded.
    pub default_deadline_ms: Option<u64>,
}

impl ServeCfg {
    pub fn new(workers: usize) -> Self {
        ServeCfg {
            workers,
            max_connections: 256,
            queue_depth: workers.max(1) * 2,
            idle_timeout: Some(Duration::from_secs(60)),
            default_deadline_ms: None,
        }
    }
}

/// Counting semaphore bounding concurrent simulations across connections,
/// with a bounded waiter queue (the admission queue).
///
/// Lock poisoning (a handler thread panicking while holding the count)
/// must not take the whole server down: the state is never left
/// mid-update, so every path recovers the guard from a poisoned mutex
/// instead of panicking every later connection.
pub struct Slots {
    state: Mutex<SlotState>,
    cv: Condvar,
    capacity: usize,
}

struct SlotState {
    free: usize,
    waiters: usize,
}

impl Slots {
    pub fn new(n: usize) -> Arc<Self> {
        let n = n.max(1);
        Arc::new(Slots {
            state: Mutex::new(SlotState {
                free: n,
                waiters: 0,
            }),
            cv: Condvar::new(),
            capacity: n,
        })
    }

    /// Total simulation slots.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Slots currently free (observability; the chaos harness asserts
    /// this returns to capacity after every fault plan).
    pub fn available(&self) -> usize {
        lock_unpoisoned(&self.state).free
    }

    /// Acquire a slot, waiting in the admission queue if none is free —
    /// unless the queue already holds `max_waiters`, in which case the
    /// request is shed (`false`) so overload produces an explicit error
    /// reply instead of an unbounded pile of blocked handlers.
    fn acquire_queued(&self, max_waiters: usize) -> bool {
        let mut st = lock_unpoisoned(&self.state);
        if st.free == 0 && st.waiters >= max_waiters {
            return false;
        }
        st.waiters += 1;
        while st.free == 0 {
            st = match self.cv.wait(st) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        st.waiters -= 1;
        st.free -= 1;
        true
    }

    fn release(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.free += 1;
        drop(st);
        self.cv.notify_one();
    }
}

/// Releases its slot on drop, so neither a panicking job nor a dead
/// client on the write path can leak a simulation slot and slowly starve
/// the server.
struct SlotGuard<'a>(&'a Slots);

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        self.0.release();
    }
}

/// Shared server state: config, slots, shutdown flag, live-connection
/// accounting for the connection cap and drain-on-shutdown.
struct Ctl {
    cfg: ServeCfg,
    slots: Arc<Slots>,
    shutdown: AtomicBool,
    live: Mutex<usize>,
    drained: Condvar,
}

impl Ctl {
    fn new(cfg: ServeCfg) -> Arc<Self> {
        // Clamp the slot count to the process-wide `--jobs` budget so a
        // server colocated with sweeps cannot oversubscribe the host.
        let slots = Slots::new(cfg.workers.min(crate::util::jobs::configured()).max(1));
        Arc::new(Ctl {
            cfg,
            slots,
            shutdown: AtomicBool::new(false),
            live: Mutex::new(0),
            drained: Condvar::new(),
        })
    }

    fn try_admit(&self) -> bool {
        let mut live = lock_unpoisoned(&self.live);
        if *live >= self.cfg.max_connections {
            return false;
        }
        *live += 1;
        true
    }

    fn conn_done(&self) {
        let mut live = lock_unpoisoned(&self.live);
        *live = live.saturating_sub(1);
        if *live == 0 {
            self.drained.notify_all();
        }
    }

    fn wait_drained(&self) {
        let mut live = lock_unpoisoned(&self.live);
        while *live > 0 {
            live = match self.drained.wait(live) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

/// Decrements the live-connection count even if the handler panics.
struct ConnGuard(Arc<Ctl>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conn_done();
    }
}

/// Serve until the listener is closed (legacy entry point: production
/// defaults for the hardening knobs).  Per-connection accept errors
/// (ECONNABORTED and friends) are transient on a loaded listener and must
/// not kill the serving loop; only the fatal "listener gone" path returns.
pub fn serve(listener: TcpListener, workers: usize) -> std::io::Result<()> {
    serve_with(listener, ServeCfg::new(workers))
}

/// Serve with explicit hardening knobs.  Blocks until the listener dies
/// or a [`ServerHandle`] (from [`spawn`]) requests shutdown, then drains
/// in-flight connections before returning.
pub fn serve_with(listener: TcpListener, cfg: ServeCfg) -> std::io::Result<()> {
    run(listener, Ctl::new(cfg))
}

fn run(listener: TcpListener, ctl: Arc<Ctl>) -> std::io::Result<()> {
    let result = accept_loop(&listener, &ctl);
    // Graceful drain: accepting has stopped (shutdown or listener
    // error); let in-flight connections finish before returning.
    ctl.wait_drained();
    result
}

fn accept_loop(listener: &TcpListener, ctl: &Arc<Ctl>) -> std::io::Result<()> {
    for stream in listener.incoming() {
        if ctl.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let stream = match stream {
            Ok(s) => s,
            Err(e) if e.kind() == ErrorKind::ConnectionAborted => continue,
            Err(e) if e.kind() == ErrorKind::ConnectionReset => continue,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if !ctl.try_admit() {
            // Connection cap reached: shed explicitly (one error line,
            // then close) instead of queueing unboundedly.
            let _ = shed(&stream, "overloaded: connection limit reached");
            continue;
        }
        let ctl = Arc::clone(ctl);
        std::thread::spawn(move || {
            let guard = ConnGuard(Arc::clone(&ctl));
            let _ = handle(stream, &ctl);
            drop(guard);
        });
    }
    Ok(())
}

fn shed(mut stream: &TcpStream, why: &str) -> std::io::Result<()> {
    let line = Json::obj(vec![("error", Json::str(why))]).to_string() + "\n";
    stream.write_all(line.as_bytes())?;
    stream.flush()
}

/// What [`next_line`] observed on the wire.
enum LineOutcome {
    Line(String),
    /// EOF, idle timeout, slow-loris budget, oversized line, fatal read
    /// error, or shutdown drain — in every case: close quietly.
    Closed,
}

/// Read one `\n`-terminated line under the connection's time budgets.
/// Reads poll at [`READ_POLL`] so the handler observes shutdown and the
/// idle/line budgets even when the client sends nothing; a line that
/// does not complete within `idle_timeout` of its *first byte* is a
/// slow-loris and closes the connection (per-read timeouts alone would
/// reset on every trickled byte).
fn next_line(reader: &mut BufReader<TcpStream>, ctl: &Ctl) -> LineOutcome {
    let mut line: Vec<u8> = Vec::new();
    let opened = Instant::now();
    let mut first_byte: Option<Instant> = None;
    loop {
        if ctl.shutdown.load(Ordering::SeqCst) && line.is_empty() {
            // Drain: a connection with no request in flight closes now;
            // a partially-received request may still complete (bounded
            // by the line budget below).
            return LineOutcome::Closed;
        }
        let (consumed, newline_at) = match reader.fill_buf() {
            Ok([]) => return LineOutcome::Closed, // EOF (possibly mid-line)
            Ok(buf) => {
                let pos = buf.iter().position(|&b| b == b'\n');
                line.extend_from_slice(match pos {
                    Some(p) => &buf[..p],
                    None => buf,
                });
                (buf.len(), pos)
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if let Some(budget) = ctl.cfg.idle_timeout {
                    // One clock covers both: idle (no line started, since
                    // the last completed request) and slow-loris (line
                    // started, stuck) — each gets `budget` from its anchor.
                    let anchor = first_byte.unwrap_or(opened);
                    if anchor.elapsed() >= budget {
                        return LineOutcome::Closed;
                    }
                }
                continue;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return LineOutcome::Closed,
        };
        match newline_at {
            Some(p) => {
                reader.consume(p + 1);
                return LineOutcome::Line(String::from_utf8_lossy(&line).into_owned());
            }
            None => {
                reader.consume(consumed);
                if first_byte.is_none() && !line.is_empty() {
                    first_byte = Some(Instant::now());
                }
                if line.len() > MAX_LINE_BYTES {
                    return LineOutcome::Closed;
                }
                if let (Some(budget), Some(fb)) = (ctl.cfg.idle_timeout, first_byte) {
                    if fb.elapsed() >= budget {
                        return LineOutcome::Closed; // slow-loris
                    }
                }
            }
        }
    }
}

fn handle(stream: TcpStream, ctl: &Ctl) -> std::io::Result<()> {
    // Short poll timeout; `next_line` implements the actual budgets.
    stream.set_read_timeout(Some(READ_POLL))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let line = match next_line(&mut reader, ctl) {
            LineOutcome::Line(l) => l,
            LineOutcome::Closed => return Ok(()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match JobSpec::parse(&line) {
            Ok(spec) => {
                if ctl.slots.acquire_queued(ctl.cfg.queue_depth) {
                    let _slot = SlotGuard(&ctl.slots);
                    run_one(spec, ctl, reader.get_ref())
                } else {
                    // The stable `overloaded` prefix is the wire contract
                    // for `JobError::Overloaded`.
                    Json::obj(vec![(
                        "error",
                        Json::str(format!(
                            "overloaded: {} slots busy, {} queued — shed (retry with backoff)",
                            ctl.slots.capacity(),
                            ctl.cfg.queue_depth
                        )),
                    )])
                    .to_string()
                }
            }
            Err(e) => Json::obj(vec![("error", Json::str(format!("bad request: {e}")))])
                .to_string(),
        };
        // A write error means the client is gone: the slot guard above
        // already released via RAII — exit the handler quietly (no
        // logging noise; the disconnect is the client's business).
        if writer
            .write_all(reply.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush())
            .is_err()
        {
            return Ok(());
        }
    }
}

/// Execute one admitted job under supervision: a per-job cancel token is
/// watched by a disconnect probe on the connection (a client that hangs
/// up cancels its own simulation instead of burning the slot), and the
/// server's default deadline applies when the spec carries none.
fn run_one(mut spec: JobSpec, ctl: &Ctl, stream: &TcpStream) -> String {
    spec.deadline_ms = spec.deadline_ms.or(ctl.cfg.default_deadline_ms);
    let token = CancelToken::new();
    let done = Arc::new(AtomicBool::new(false));
    if let Ok(probe) = stream.try_clone() {
        let token = token.clone();
        let done = Arc::clone(&done);
        // Detached: exits within one WATCH_POLL of `done` (or of the
        // disconnect it was watching for).
        std::thread::spawn(move || disconnect_watch(probe, token, done));
    }
    let result = supervisor::execute_with_token(&spec, token);
    done.store(true, Ordering::SeqCst);
    result.to_json().to_string()
}

/// Poll the connection for EOF/reset while a job runs.  `peek` never
/// consumes, so pipelined follow-up requests are left for the handler.
fn disconnect_watch(stream: TcpStream, token: CancelToken, done: Arc<AtomicBool>) {
    let _ = stream.set_read_timeout(Some(WATCH_POLL));
    let mut probe = [0u8; 1];
    while !done.load(Ordering::SeqCst) {
        match stream.peek(&mut probe) {
            Ok(0) => {
                token.cancel(); // orderly shutdown from the client
                return;
            }
            // Data waiting (a pipelined request): nothing to learn from
            // peeking it again immediately — sleep through the poll.
            Ok(_) => std::thread::sleep(WATCH_POLL),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(_) => {
                token.cancel(); // reset/abort: the client is gone
                return;
            }
        }
    }
}

/// A server running on its own thread, with its listening address, its
/// slot semaphore (for leak assertions), and graceful shutdown.
pub struct ServerHandle {
    addr: SocketAddr,
    ctl: Arc<Ctl>,
    thread: Option<std::thread::JoinHandle<std::io::Result<()>>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The server's slot semaphore (observability for tests).
    pub fn slots(&self) -> Arc<Slots> {
        Arc::clone(&self.ctl.slots)
    }

    /// Stop accepting, drain in-flight connections, and return the
    /// serve loop's result.
    pub fn shutdown(mut self) -> std::io::Result<()> {
        self.ctl.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept; the loop re-checks the flag first.
        let _ = TcpStream::connect(self.addr);
        match self.thread.take() {
            Some(t) => t.join().unwrap_or(Ok(())),
            None => Ok(()),
        }
    }
}

/// Bind `addr` (e.g. `127.0.0.1:0`) and serve on a background thread.
pub fn spawn(addr: &str, cfg: ServeCfg) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let ctl = Ctl::new(cfg);
    let run_ctl = Arc::clone(&ctl);
    let thread = std::thread::spawn(move || run(listener, run_ctl));
    Ok(ServerHandle {
        addr: local,
        ctl,
        thread: Some(thread),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{JobError, JobResult, SimModeSpec, TargetSpec, Workload};
    use std::io::Read;

    fn start_server(workers: usize) -> std::net::SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = serve(listener, workers);
        });
        addr
    }

    fn gemm_spec(id: u64) -> JobSpec {
        JobSpec {
            id,
            target: TargetSpec::Systolic { rows: 2, cols: 2 },
            workload: Workload::Gemm {
                m: 4,
                k: 4,
                n: 4,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: Default::default(),
            max_cycles: 10_000_000,
            platform: None,
            deadline_ms: None,
        }
    }

    /// A job that (with `ACADL_CHAOS=1`) holds its slot until its cancel
    /// token trips — the controllable long-running request for the
    /// backpressure and disconnect tests.
    fn stall_spec(id_low: u64, deadline_ms: Option<u64>) -> JobSpec {
        std::env::set_var("ACADL_CHAOS", "1");
        JobSpec {
            id: crate::coordinator::job::CHAOS_STALL_MARK | id_low,
            deadline_ms,
            ..gemm_spec(0)
        }
    }

    fn submit(stream: &mut TcpStream, spec: &JobSpec) {
        let line = spec.to_json().to_string() + "\n";
        stream.write_all(line.as_bytes()).unwrap();
    }

    fn read_reply(stream: TcpStream) -> String {
        let mut reader = BufReader::new(stream);
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        reply
    }

    #[test]
    fn serves_a_job_over_tcp() {
        let addr = start_server(2);
        let spec = JobSpec {
            id: 42,
            target: TargetSpec::Gamma { units: 1 },
            workload: Workload::Gemm {
                m: 8,
                k: 8,
                n: 8,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: Default::default(),
            max_cycles: 10_000_000,
            platform: None,
            deadline_ms: None,
        };
        let mut stream = TcpStream::connect(addr).expect("connect");
        submit(&mut stream, &spec);
        let reply = read_reply(stream);
        let result =
            JobResult::from_json(&Json::parse(reply.trim()).unwrap()).expect("result json");
        assert_eq!(result.id, 42);
        assert_eq!(result.error, None);
        assert!(result.cycles > 0);
        assert_eq!(result.numerics_ok, Some(true));
    }

    #[test]
    fn bad_request_gets_error_line() {
        let addr = start_server(1);
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(b"this is not json\n").unwrap();
        let reply = read_reply(stream);
        assert!(reply.contains("bad request"), "{reply}");
    }

    #[test]
    fn multiple_jobs_one_connection_preserve_order() {
        let addr = start_server(2);
        let mut stream = TcpStream::connect(addr).expect("connect");
        for id in 0..3u64 {
            let spec = JobSpec {
                mode: SimModeSpec::Estimate,
                ..gemm_spec(id)
            };
            submit(&mut stream, &spec);
        }
        let mut reader = BufReader::new(stream);
        for id in 0..3u64 {
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            let result = JobResult::from_json(&Json::parse(reply.trim()).unwrap()).unwrap();
            assert_eq!(result.id, id);
        }
    }

    /// Satellite: a client that dies mid-execution must not wedge the
    /// server — the disconnect watch cancels the simulation, the write
    /// error releases the slot quietly, and the next connection is
    /// served.
    #[test]
    fn dead_client_mid_execution_releases_the_slot() {
        let handle = spawn(
            "127.0.0.1:0",
            ServeCfg {
                idle_timeout: Some(Duration::from_secs(5)),
                ..ServeCfg::new(1)
            },
        )
        .expect("spawn");
        let slots = handle.slots();
        assert_eq!(slots.available(), slots.capacity());

        let mut victim = TcpStream::connect(handle.addr()).expect("connect");
        // 10 s deadline: only the disconnect can end this stall quickly.
        submit(&mut victim, &stall_spec(1, Some(10_000)));
        std::thread::sleep(Duration::from_millis(100)); // job is now holding the slot
        drop(victim); // kill the socket mid-execution

        // The watch cancels the job and the slot comes back.
        let deadline = Instant::now() + Duration::from_secs(5);
        while slots.available() < slots.capacity() {
            assert!(Instant::now() < deadline, "slot leaked after client death");
            std::thread::sleep(Duration::from_millis(10));
        }

        // And a following connection still gets served.
        let mut next = TcpStream::connect(handle.addr()).expect("connect after death");
        submit(&mut next, &gemm_spec(7));
        let reply = read_reply(next);
        let result = JobResult::from_json(&Json::parse(reply.trim()).unwrap()).unwrap();
        assert_eq!(result.id, 7);
        assert_eq!(result.error, None, "{reply}");
        handle.shutdown().expect("shutdown");
    }

    /// A full admission queue sheds with an explicit `overloaded` error
    /// instead of hanging the client.
    #[test]
    fn full_admission_queue_sheds_with_overloaded() {
        let handle = spawn(
            "127.0.0.1:0",
            ServeCfg {
                queue_depth: 0, // no waiting: busy slot ⇒ shed
                ..ServeCfg::new(1)
            },
        )
        .expect("spawn");

        let mut holder = TcpStream::connect(handle.addr()).expect("connect");
        submit(&mut holder, &stall_spec(2, Some(2_000)));
        std::thread::sleep(Duration::from_millis(200)); // stall job owns the slot

        let mut shed_client = TcpStream::connect(handle.addr()).expect("connect");
        submit(&mut shed_client, &gemm_spec(8));
        let reply = read_reply(shed_client);
        assert!(reply.contains("overloaded"), "{reply}");
        assert_eq!(
            JobError::classify(
                Json::parse(reply.trim())
                    .unwrap()
                    .field("error")
                    .unwrap()
                    .as_str()
                    .unwrap()
            ),
            JobError::Overloaded
        );

        // The holder's job ends via its deadline and reports it.
        let reply = read_reply(holder);
        let result = JobResult::from_json(&Json::parse(reply.trim()).unwrap()).unwrap();
        assert_eq!(result.error_class(), Some(JobError::Deadline), "{reply}");
        handle.shutdown().expect("shutdown");
    }

    /// `deadline_ms` on the wire bounds a job that would otherwise hold
    /// its slot for seconds.
    #[test]
    fn wire_deadline_bounds_a_job() {
        let handle = spawn("127.0.0.1:0", ServeCfg::new(1)).expect("spawn");
        let mut stream = TcpStream::connect(handle.addr()).expect("connect");
        let t = Instant::now();
        submit(&mut stream, &stall_spec(3, Some(150)));
        let reply = read_reply(stream);
        let result = JobResult::from_json(&Json::parse(reply.trim()).unwrap()).unwrap();
        assert_eq!(result.error_class(), Some(JobError::Deadline), "{reply}");
        assert!(
            t.elapsed() < Duration::from_secs(4),
            "deadline did not bound the stall: {:?}",
            t.elapsed()
        );
        handle.shutdown().expect("shutdown");
    }

    /// Idle connections (and slow-loris writers) are closed after the
    /// idle budget; the server keeps serving others.
    #[test]
    fn idle_connection_times_out() {
        let handle = spawn(
            "127.0.0.1:0",
            ServeCfg {
                idle_timeout: Some(Duration::from_millis(150)),
                ..ServeCfg::new(1)
            },
        )
        .expect("spawn");
        let mut idle = TcpStream::connect(handle.addr()).expect("connect");
        let mut buf = [0u8; 8];
        // The server closes us: read returns 0 within a few poll ticks.
        idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let n = idle.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "expected the server to close the idle connection");

        let mut live = TcpStream::connect(handle.addr()).expect("connect");
        submit(&mut live, &gemm_spec(9));
        let reply = read_reply(live);
        assert!(reply.contains("\"cycles\""), "{reply}");
        handle.shutdown().expect("shutdown");
    }

    /// Shutdown stops accepting, finishes in-flight work, and returns.
    #[test]
    fn graceful_shutdown_drains_in_flight_connections() {
        let handle = spawn("127.0.0.1:0", ServeCfg::new(2)).expect("spawn");
        let addr = handle.addr();
        let mut stream = TcpStream::connect(addr).expect("connect");
        submit(&mut stream, &gemm_spec(5));
        let reply = read_reply(stream); // in-flight job completed
        assert!(reply.contains("\"cycles\""), "{reply}");
        handle.shutdown().expect("clean shutdown");
        // The listener is gone: new connections are refused (or reset).
        let refused = TcpStream::connect_timeout(&addr, Duration::from_millis(500));
        if let Ok(mut s) = refused {
            // Accepted by the OS backlog before close — but nobody serves
            // it: reads see EOF.
            let mut buf = [0u8; 1];
            s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            assert_eq!(s.read(&mut buf).unwrap_or(0), 0);
        }
    }
}
