//! The supervision layer: the one place where a job body meets the
//! outside world's failure modes.  Every execution path that runs
//! *other people's requests* — the pool's worker threads, the TCP
//! server's connection handlers — routes through [`run_supervised`], so
//! the containment policy lives in exactly one spot:
//!
//! * **Panic isolation.**  The body runs under `catch_unwind`; a panic
//!   becomes an error [`JobResult`] carrying the panic message with a
//!   stable `panic: ` prefix ([`JobError::Panic`]).  RAII guards inside
//!   the body (slots, jobs-budget leases, cancel-token installs, pooled
//!   effects) unwind normally, so one poisoned job never leaks
//!   resources, takes down a sweep, or kills a connection.
//! * **Cancellation scoping.**  [`execute_with_token`] installs a
//!   caller-provided [`CancelToken`] (e.g. the server's
//!   client-disconnect watch) around the body; `execute_on` chains the
//!   job's own `deadline_ms` onto it.  The install guard is restored
//!   even on unwind — the `catch_unwind` boundary is *outside* the
//!   install, so a panicking job cannot leave its token behind on a
//!   pool thread that will run other jobs.
//!
//! What this layer deliberately does **not** do: kill threads, time out
//! preemptively, or retry.  Cancellation is cooperative (the sim loops
//! poll the token), and retry policy belongs to callers who know
//! whether a job is idempotent (all of ours are — results are memoized
//! by canonical key).

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::mapping::uma::Machine;
use crate::util::cancel::{self, CancelToken};

use super::job::{self, JobResult, JobSpec};

/// Best-effort text of a panic payload (`&str` and `String` payloads
/// cover `panic!`/`assert!`/`unwrap` in practice).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Run a job body with panic containment: a panic becomes an error
/// result (`panic: <message>`) instead of propagating into the calling
/// worker or connection handler.
pub fn run_supervised(spec: &JobSpec, body: impl FnOnce() -> JobResult) -> JobResult {
    let start = std::time::Instant::now();
    // AssertUnwindSafe: the body only touches `Arc`-shared state guarded
    // by poison-recovering locks (`lock_unpoisoned`) or atomics, and the
    // per-job state it mutates dies with the unwind.
    match catch_unwind(AssertUnwindSafe(body)) {
        Ok(result) => result,
        Err(payload) => JobResult::panicked(
            spec,
            panic_message(payload.as_ref()),
            start.elapsed().as_micros() as u64,
        ),
    }
}

/// [`job::execute`] under supervision (standalone / server path).
pub fn execute(spec: &JobSpec) -> JobResult {
    run_supervised(spec, || job::execute(spec))
}

/// [`job::execute_on`] under supervision (pool path, shared machine).
pub fn execute_on(machine: &Machine, spec: &JobSpec) -> JobResult {
    run_supervised(spec, || job::execute_on(machine, spec))
}

/// Supervised execution with `token` installed for the duration of the
/// job: the server's per-connection disconnect watch threads through
/// here, and `execute_on` chains the job's own `deadline_ms` onto it.
/// The install lives *inside* the catch so an unwind restores the
/// thread's previous token before the panic is converted.
pub fn execute_with_token(spec: &JobSpec, token: CancelToken) -> JobResult {
    run_supervised(spec, || {
        let _guard = cancel::install(token);
        job::execute(spec)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{JobError, SimModeSpec, TargetSpec, Workload};

    fn spec(id: u64) -> JobSpec {
        JobSpec {
            id,
            target: TargetSpec::Systolic { rows: 2, cols: 2 },
            workload: Workload::Gemm {
                m: 4,
                k: 4,
                n: 4,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: Default::default(),
            max_cycles: 10_000_000,
            platform: None,
            deadline_ms: None,
        }
    }

    #[test]
    fn panicking_body_becomes_error_result() {
        let s = spec(1);
        let r = run_supervised(&s, || panic!("boom {}", 42));
        assert_eq!(r.id, 1);
        assert_eq!(r.error.as_deref(), Some("panic: boom 42"));
        assert_eq!(r.error_class(), Some(JobError::Panic));
        assert_eq!(r.cycles, 0);
    }

    #[test]
    fn healthy_body_passes_through() {
        let s = spec(2);
        let r = execute(&s);
        assert_eq!(r.error, None, "{r:?}");
        assert!(r.cycles > 0);
    }

    #[test]
    fn panic_does_not_leave_an_installed_token_behind() {
        let s = spec(3);
        let token = CancelToken::new();
        let r = run_supervised(&s, || {
            let _g = cancel::install(token);
            panic!("mid-job panic with a token installed");
        });
        assert_eq!(r.error_class(), Some(JobError::Panic));
        // The unwind dropped the install guard: this thread is clean.
        assert!(cancel::current().is_none());
    }

    #[test]
    fn token_install_scopes_to_the_job() {
        let s = spec(4);
        let token = CancelToken::new();
        token.cancel();
        let r = execute_with_token(&s, token);
        // The gemm is small enough to finish between polls — either a
        // clean result or a structured cancellation, never a hang; and
        // the token never outlives the call.
        if let Some(class) = r.error_class() {
            assert_eq!(class, JobError::Cancelled, "{:?}", r.error);
        }
        assert!(cancel::current().is_none());
    }
}
