//! The coordinator: an async job system for simulation campaigns.
//!
//! The paper's closing pitch (§7) is using the timing simulation "in the
//! optimization loop of hardware-aware NAS and DNN/HW Co-Design" — which
//! means *many* (architecture × workload × mapping) evaluations.  This
//! layer is the production harness for that loop:
//!
//! * [`job`] — serializable job descriptors (target config, workload,
//!   simulation mode) and result rows.
//! * [`pool`] — a tokio worker pool executing jobs on blocking threads,
//!   **batched by target** so each architecture graph is built once and
//!   shared across the jobs that sweep workloads on it.
//! * [`server`] — a line-delimited-JSON TCP front-end: external tools
//!   (NAS searchers, DSE scripts) submit jobs and stream results.

pub mod job;
pub mod pool;
pub mod server;

pub use job::{JobResult, JobSpec, SimModeSpec, TargetSpec, Workload};
pub use pool::{run_jobs, run_jobs_blocking};
