//! The coordinator: an async job system for simulation campaigns.
//!
//! The paper's closing pitch (§7) is using the timing simulation "in the
//! optimization loop of hardware-aware NAS and DNN/HW Co-Design" — which
//! means *many* (architecture × workload × mapping) evaluations.  This
//! layer is the production harness for that loop:
//!
//! * [`job`] — serializable job descriptors (target config, workload,
//!   simulation mode) and result rows.
//! * [`machines`] — the built-machine cache: each distinct target's
//!   architecture graph builds **once per process** (keyed by the
//!   canonical config hash) and is shared across pool workers, server
//!   connections, and DSE waves.
//! * [`pool`] — a tokio worker pool executing jobs on blocking threads,
//!   **batched by target** so each architecture graph is built once and
//!   shared across the jobs that sweep workloads on it.
//! * [`server`] — a line-delimited-JSON TCP front-end: external tools
//!   (NAS searchers, DSE scripts) submit jobs and stream results.
//! * [`supervisor`] — the fault-containment wrapper every job body runs
//!   under: panic isolation (`catch_unwind` → error result) and
//!   cancellation scoping (deadline / disconnect tokens).

pub mod job;
pub mod machines;
pub mod pool;
pub mod server;
pub mod supervisor;

/// Lock with poison recovery, shared by the pool and the machine cache: a
/// worker that panicked mid-job poisons the mutex, but the state each of
/// these guards (a queue receiver, an immutable-machine map) is never
/// left mid-update — so recover the guard instead of cascading panics
/// through every later `.lock().expect(..)`.
pub(crate) fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

pub use job::{
    JobError, JobResult, JobSpec, PlatformSpec, RunCapture, SimModeSpec, TargetSpec, Workload,
};
pub use machines::build_cached;
pub use pool::{run_jobs, run_jobs_blocking};
